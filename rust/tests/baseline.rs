//! Golden-baseline regression-mode integration tests: write a baseline
//! from a small sweep, compare clean, then perturb one cell and assert
//! the diff names it — at the library level and through the real
//! `repro sweep --write-baseline` / `--compare` CLI (exit code 2).

use std::process::{Command, Output};

use micdl::config::ArchSpec;
use micdl::sweep::baseline::DEFAULT_TOLERANCE;
use micdl::sweep::{Baseline, GridSpec, Strategy, SweepRunner};
use micdl::util::json::Json;
use micdl::util::tmp::TempDir;

fn repro(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .output()
        .expect("spawn repro")
}

fn small_grid() -> GridSpec {
    GridSpec {
        archs: vec![ArchSpec::small()],
        threads: vec![1, 15],
        strategies: vec![Strategy::A, Strategy::B],
        measure: true,
        ..GridSpec::default()
    }
}

// ---------------------------------------------------------------------------
// Library level
// ---------------------------------------------------------------------------

#[test]
fn write_then_compare_round_trips_clean() {
    let res = SweepRunner::serial().run(&small_grid()).unwrap();
    let base = Baseline::from_results(&res).unwrap();
    // Through the file format, against a fresh run of the embedded grid.
    let reparsed = Baseline::parse(&base.to_json().emit()).unwrap();
    let rerun = SweepRunner::new(0).run(&reparsed.grid().unwrap()).unwrap();
    let report = reparsed.compare(&rerun, DEFAULT_TOLERANCE).unwrap();
    assert!(report.is_clean(), "{}", report.render());
    assert_eq!(report.cells_compared, 4);
}

#[test]
fn perturbed_cell_fails_and_is_named() {
    let res = SweepRunner::serial().run(&small_grid()).unwrap();
    let mut base = Baseline::from_results(&res).unwrap();
    let victim = base.cells[3].key();
    base.cells[3].total_s *= 1.02;
    let report = base.compare(&res, DEFAULT_TOLERANCE).unwrap();
    assert!(!report.is_clean());
    assert_eq!(report.mismatches.len(), 1);
    assert_eq!(report.mismatches[0].cell, victim);
    assert_eq!(report.mismatches[0].field, "total_s");
    assert!(report.render().contains(&victim));
}

// ---------------------------------------------------------------------------
// The committed CI baseline
// ---------------------------------------------------------------------------

#[test]
fn committed_ci_smoke_baseline_matches_fresh_sweep() {
    // The golden file CI pins (baselines/ci_smoke.json) must stay in
    // lockstep with the models — this is the same check the CI step
    // runs, executed inside the tier-1 test gate. On an intentional
    // model change, regenerate the file (baselines/README.md).
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../baselines/ci_smoke.json");
    let base = Baseline::load(&path).expect("load baselines/ci_smoke.json");
    assert_eq!(base.cells.len(), 42, "default grid is 42 cells");
    let res = SweepRunner::serial().run(&base.grid().unwrap()).unwrap();
    let report = base.compare(&res, DEFAULT_TOLERANCE).unwrap();
    assert!(report.is_clean(), "{}", report.render());
    assert_eq!(report.cells_compared, 42);
}

// ---------------------------------------------------------------------------
// CLI level (the acceptance path)
// ---------------------------------------------------------------------------

#[test]
fn cli_write_baseline_then_compare_passes_then_fails_on_perturbation() {
    let dir = TempDir::new("baseline-cli").unwrap();
    let path = dir.path().join("golden.json");
    let path_str = path.to_str().unwrap();

    // 1. Write a baseline from a small measured sweep.
    let out = repro(&[
        "sweep", "--arch", "small", "--threads", "1,15", "--strategy", "both",
        "--measure", "--serial", "--write-baseline", path_str,
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = std::fs::read_to_string(&path).unwrap();
    let doc = Json::parse(&text).unwrap();
    assert_eq!(doc.get("cells").unwrap().as_arr().unwrap().len(), 4);

    // 2. `--compare` alone re-runs the baseline's embedded grid: clean,
    //    exit 0, machine-readable report on stdout.
    let out = repro(&["sweep", "--compare", path_str, "--serial"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let report = Json::parse(String::from_utf8_lossy(&out.stdout).trim()).unwrap();
    assert_eq!(report.get("clean").unwrap().as_bool(), Some(true));
    assert_eq!(report.get("cells_compared").unwrap().as_usize(), Some(4));
    assert_eq!(
        report.get("mismatches").unwrap().as_arr().unwrap().len(),
        0
    );

    // 3. Perturb one cell in the baseline file and compare again: exit
    //    code 2 and the offending scenario named in both report forms.
    let mut base = Baseline::parse(&text).unwrap();
    let victim = base.cells[1].key();
    base.cells[1].delta_pct = base.cells[1].delta_pct.map(|d| d + 0.5);
    std::fs::write(&path, base.to_json().emit()).unwrap();
    let out = repro(&["sweep", "--compare", path_str, "--serial"]);
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(2), "regression must exit 2");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    let report = Json::parse(stdout.trim()).unwrap();
    assert_eq!(report.get("clean").unwrap().as_bool(), Some(false));
    let mismatches = report.get("mismatches").unwrap().as_arr().unwrap();
    assert_eq!(mismatches.len(), 1);
    assert_eq!(
        mismatches[0].get("cell").unwrap().as_str(),
        Some(victim.as_str())
    );
    assert_eq!(mismatches[0].get("field").unwrap().as_str(), Some("delta_pct"));
    assert!(stderr.contains("REGRESSION"), "{stderr}");
    assert!(stderr.contains(&victim), "{stderr}");
}

#[test]
fn cli_compare_with_explicit_grid_flags_overrides_baseline_grid() {
    let dir = TempDir::new("baseline-cli-grid").unwrap();
    let path = dir.path().join("golden.json");
    let path_str = path.to_str().unwrap();
    let out = repro(&[
        "sweep", "--arch", "small", "--threads", "1,15", "--strategy", "a",
        "--serial", "--write-baseline", path_str,
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    // A narrower explicit grid leaves baseline cells unmatched → exit 2
    // with the missing cells reported.
    let out = repro(&[
        "sweep", "--arch", "small", "--threads", "1", "--strategy", "a",
        "--serial", "--compare", path_str,
    ]);
    assert_eq!(out.status.code(), Some(2));
    let report = Json::parse(String::from_utf8_lossy(&out.stdout).trim()).unwrap();
    assert_eq!(
        report.get("missing_in_run").unwrap().as_arr().unwrap().len(),
        1
    );
}

#[test]
fn cli_rejects_unknown_and_valueless_sweep_flags() {
    // A typo'd --compare must not silently skip the comparison (exit 0
    // would make a CI gate vacuous).
    let out = repro(&["sweep", "--serial", "--comapre", "x.json"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown sweep flag"));
    // So must a --compare with its value swallowed by the next flag.
    let out = repro(&["sweep", "--compare", "--serial"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("needs a value"));
}

#[test]
fn cli_rejects_bad_tolerance() {
    let dir = TempDir::new("baseline-cli-tol").unwrap();
    let path = dir.path().join("golden.json");
    let path_str = path.to_str().unwrap();
    let out = repro(&[
        "sweep", "--arch", "small", "--threads", "1", "--strategy", "a",
        "--serial", "--write-baseline", path_str,
    ]);
    assert!(out.status.success());
    let out = repro(&["sweep", "--compare", path_str, "--tolerance", "nope"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("tolerance"));
}
