//! Measured-mode conformance harness integration tests: capture a
//! baseline, check it clean, perturb it and watch it fail — at the
//! library level, against the committed `baselines/measured_smoke.json`,
//! and through the real `repro conformance` CLI (exit code 2).

use std::process::{Command, Output};

use micdl::sweep::conformance::{self, ConformanceBaseline};
use micdl::sweep::SweepRunner;
use micdl::util::json::Json;
use micdl::util::tmp::TempDir;

fn repro(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .output()
        .expect("spawn repro")
}

fn committed_baseline_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../baselines/measured_smoke.json")
}

fn committed_closed_loop_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../baselines/closed_loop_smoke.json")
}

fn committed_residual_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../baselines/residual_smoke.json")
}

// ---------------------------------------------------------------------------
// Library level
// ---------------------------------------------------------------------------

#[test]
fn capture_then_check_round_trips_clean() {
    let runner = SweepRunner::new(0);
    let base = ConformanceBaseline::capture(&runner).unwrap();
    // Tables IX (6 groups) + X (6) + XI (1), claims for both strategies.
    assert_eq!(base.grids.len(), 3);
    assert_eq!(
        base.grids.iter().map(|g| g.bands.len()).sum::<usize>(),
        13
    );
    assert_eq!(base.claims.len(), 2);
    // Through the file format, against a fresh re-run of the embedded
    // grids.
    let reparsed = ConformanceBaseline::parse(&base.to_json().emit()).unwrap();
    let report = reparsed.check(&runner).unwrap();
    assert!(report.is_clean(), "{}", report.render());
    assert_eq!(report.scenarios, 42 + 24 + 18);
    assert_eq!(report.bands.len(), 13);
    assert_eq!(report.claims.len(), 2);
}

#[test]
fn serial_and_parallel_checks_agree_bit_for_bit() {
    // The acceptance criterion: measured-mode sweeps are bit-identical
    // parallel vs serial, so the whole conformance report is too.
    let base = ConformanceBaseline::capture(&SweepRunner::serial()).unwrap();
    let serial = base.check(&SweepRunner::serial()).unwrap();
    let parallel = base.check(&SweepRunner::new(4)).unwrap();
    assert_eq!(serial.to_json().emit(), parallel.to_json().emit());
    assert!(serial.is_clean(), "{}", serial.render());
}

// ---------------------------------------------------------------------------
// The committed measured golden baseline
// ---------------------------------------------------------------------------

#[test]
fn committed_measured_smoke_baseline_is_clean() {
    // The measured-mode analogue of the ci_smoke check: the Δ bands in
    // baselines/measured_smoke.json must hold against a fresh run of the
    // Tables IX-XI grids. This is the paper's accuracy claim as a
    // regression test — on an intentional simulator or model change,
    // regenerate the file (baselines/README.md).
    let base = ConformanceBaseline::load(&committed_baseline_path())
        .expect("load baselines/measured_smoke.json");
    assert_eq!(base.grids.len(), 3);
    let ids: Vec<&str> = base.grids.iter().map(|g| g.id.as_str()).collect();
    assert_eq!(ids, vec!["table9", "table10", "table11"]);
    let report = base.check(&SweepRunner::serial()).unwrap();
    assert!(report.is_clean(), "{}", report.render());
    assert_eq!(report.scenarios, 84);
    // The claims bound the paper's headline numbers: mean Δ over the
    // Table IX domain stays within ≈ 15 % (a) and ≈ 11 % (b).
    assert_eq!(report.claims.len(), 2);
    for claim in &report.claims {
        assert!(claim.pass);
        assert!(
            claim.observed_mean_pct <= claim.claim.band.ceiling_pct,
            "{} observed {} ceiling {}",
            claim.claim.strategy,
            claim.observed_mean_pct,
            claim.claim.band.ceiling_pct
        );
        assert!(claim.claim.band.paper_pct > 10.0 && claim.claim.band.paper_pct < 16.0);
    }
}

#[test]
fn committed_baseline_matches_capture_within_tolerance() {
    // The committed file was seeded by generate_measured_smoke.py; a
    // live capture must agree with it band for band (same grids, same
    // points, means within each band's own tolerance).
    let committed = ConformanceBaseline::load(&committed_baseline_path()).unwrap();
    let captured = ConformanceBaseline::capture(&SweepRunner::serial()).unwrap();
    for (want, got) in committed.grids.iter().zip(captured.grids.iter()) {
        assert_eq!(want.id, got.id);
        assert_eq!(want.bands.len(), got.bands.len(), "{}", want.id);
        for (wb, gb) in want.bands.iter().zip(got.bands.iter()) {
            assert_eq!((wb.arch.as_str(), wb.strategy), (gb.arch.as_str(), gb.strategy));
            assert_eq!(wb.points, gb.points);
            assert!(
                (wb.mean_delta_pct - gb.mean_delta_pct).abs() <= wb.mean_tol_pp,
                "{}/{}/{}: committed mean {} vs captured {}",
                want.id,
                wb.arch,
                wb.strategy,
                wb.mean_delta_pct,
                gb.mean_delta_pct
            );
        }
    }
}

// ---------------------------------------------------------------------------
// The committed closed-loop golden baseline (--params sim)
// ---------------------------------------------------------------------------

#[test]
fn committed_closed_loop_baseline_is_clean() {
    // The closed-loop analogue: the Table IX grid under --params sim
    // (model parameters probed from the measuring simulator) must hold
    // the Δ bands pinned in baselines/closed_loop_smoke.json.
    let base = ConformanceBaseline::load(&committed_closed_loop_path())
        .expect("load baselines/closed_loop_smoke.json");
    assert_eq!(base.grids.len(), 1);
    assert_eq!(base.grids[0].id, conformance::CLOSED_LOOP_CLAIM_GRID);
    assert_eq!(base.grids[0].bands.len(), 6);
    let report = base.check(&SweepRunner::serial()).unwrap();
    assert!(report.is_clean(), "{}", report.render());
    assert_eq!(report.scenarios, 42);
    assert_eq!(report.claims.len(), 2);
    // Strategy (b) fully closes the loop: its probed parameters remove
    // the Table III measurement offset, so the observed mean runs well
    // under the open-loop run (≈ 6.1 %) — and far under the paper claim.
    let b = report
        .claims
        .iter()
        .find(|c| c.claim.strategy == micdl::sweep::Strategy::B)
        .unwrap();
    assert!(b.pass);
    assert!(b.observed_mean_pct < 6.0, "{}", b.observed_mean_pct);
    // Strategy (a) closes fully too since the calibration subsystem fits
    // the op-count→cycles mapping against the measuring simulator
    // (calibration::ComputedSource): the medium-CNN band that used to
    // pin the computed-vs-paper op-count gap at ~58 % now sits in the
    // structural few percent, and the claim ceiling collapses back to
    // the paper value.
    let a = report
        .claims
        .iter()
        .find(|c| c.claim.strategy == micdl::sweep::Strategy::A)
        .unwrap();
    assert!(a.pass);
    assert!(a.observed_mean_pct < 6.0, "{}", a.observed_mean_pct);
    assert!(a.claim.band.ceiling_pct <= a.claim.band.paper_pct + 1e-9);
    let medium_a = report
        .bands
        .iter()
        .find(|bc| bc.band.arch == "medium" && bc.band.strategy == micdl::sweep::Strategy::A)
        .unwrap();
    assert!(
        medium_a.observed_mean_pct < 10.0,
        "medium/a {} (pre-calibration: ~58%)",
        medium_a.observed_mean_pct
    );
}

#[test]
fn committed_closed_loop_matches_capture_within_tolerance() {
    let committed = ConformanceBaseline::load(&committed_closed_loop_path()).unwrap();
    let captured = ConformanceBaseline::capture_closed_loop(&SweepRunner::serial()).unwrap();
    assert_eq!(committed.grids.len(), captured.grids.len());
    for (want, got) in committed.grids.iter().zip(captured.grids.iter()) {
        assert_eq!(want.id, got.id);
        assert_eq!(want.bands.len(), got.bands.len());
        for (wb, gb) in want.bands.iter().zip(got.bands.iter()) {
            assert_eq!((wb.arch.as_str(), wb.strategy), (gb.arch.as_str(), gb.strategy));
            assert_eq!(wb.points, gb.points);
            assert!(
                (wb.mean_delta_pct - gb.mean_delta_pct).abs() <= wb.mean_tol_pp,
                "{}/{}/{}: committed mean {} vs captured {}",
                want.id,
                wb.arch,
                wb.strategy,
                wb.mean_delta_pct,
                gb.mean_delta_pct
            );
        }
    }
}

// ---------------------------------------------------------------------------
// The committed residual golden baseline (strategy (c))
// ---------------------------------------------------------------------------

#[test]
fn committed_residual_baseline_is_clean_and_orders_c_below_b() {
    // The tentpole pin: baselines/residual_smoke.json holds the Tables
    // IX-XI grids under strategies (b) and (c), and every (c) band —
    // the sweep-trained residual regressor stacked on (b) — must sit
    // strictly below its (b) partner.
    let base = ConformanceBaseline::load(&committed_residual_path())
        .expect("load baselines/residual_smoke.json");
    assert_eq!(base.grids.len(), 3);
    let ids: Vec<&str> = base.grids.iter().map(|g| g.id.as_str()).collect();
    assert_eq!(ids, vec!["table9_residual", "table10_residual", "table11_residual"]);
    assert_eq!(base.grids[0].id, conformance::RESIDUAL_CLAIM_GRID);
    // The pinned bands already encode the ordering.
    for grid in &base.grids {
        for cb in grid.bands.iter().filter(|b| b.strategy == micdl::sweep::Strategy::C) {
            let bb = grid
                .bands
                .iter()
                .find(|b| b.strategy == micdl::sweep::Strategy::B && b.arch == cb.arch)
                .expect("every (c) band has a (b) partner");
            assert!(
                cb.mean_delta_pct < bb.mean_delta_pct,
                "{}/{}: pinned (c) {} !< (b) {}",
                grid.id,
                cb.arch,
                cb.mean_delta_pct,
                bb.mean_delta_pct
            );
        }
    }
    // A fresh run holds the bands, the claims, and the ordering.
    let report = base.check(&SweepRunner::serial()).unwrap();
    assert!(report.is_clean(), "{}", report.render());
    assert_eq!(report.scenarios, 42 + 24 + 36);
    assert_eq!(report.bands.len(), 14);
    // Both claims bound against strategy (b)'s Table IX paper bar…
    assert_eq!(report.claims.len(), 2);
    for claim in &report.claims {
        assert!(claim.pass);
        assert!(
            (claim.claim.band.paper_pct - 11.35).abs() < 0.01,
            "claim bar {}",
            claim.claim.band.paper_pct
        );
    }
    // …and observed (c) lands far below observed (b) on the claim grid.
    let b = report
        .claims
        .iter()
        .find(|c| c.claim.strategy == micdl::sweep::Strategy::B)
        .unwrap();
    let c = report
        .claims
        .iter()
        .find(|c| c.claim.strategy == micdl::sweep::Strategy::C)
        .unwrap();
    assert!(
        c.observed_mean_pct < b.observed_mean_pct,
        "(c) {} !< (b) {}",
        c.observed_mean_pct,
        b.observed_mean_pct
    );
    assert!(c.observed_mean_pct < 2.0, "(c) mean Δ {}", c.observed_mean_pct);
}

#[test]
fn committed_residual_matches_capture_within_tolerance() {
    let committed = ConformanceBaseline::load(&committed_residual_path()).unwrap();
    let captured = ConformanceBaseline::capture_residual(&SweepRunner::serial()).unwrap();
    assert_eq!(committed.grids.len(), captured.grids.len());
    for (want, got) in committed.grids.iter().zip(captured.grids.iter()) {
        assert_eq!(want.id, got.id);
        assert_eq!(want.bands.len(), got.bands.len(), "{}", want.id);
        for (wb, gb) in want.bands.iter().zip(got.bands.iter()) {
            assert_eq!((wb.arch.as_str(), wb.strategy), (gb.arch.as_str(), gb.strategy));
            assert_eq!(wb.points, gb.points);
            assert!(
                (wb.mean_delta_pct - gb.mean_delta_pct).abs() <= wb.mean_tol_pp,
                "{}/{}/{}: committed mean {} vs captured {}",
                want.id,
                wb.arch,
                wb.strategy,
                wb.mean_delta_pct,
                gb.mean_delta_pct
            );
        }
    }
}

// ---------------------------------------------------------------------------
// CLI level (the acceptance path)
// ---------------------------------------------------------------------------

#[test]
fn cli_check_committed_baseline_writes_report_and_exits_zero() {
    let dir = TempDir::new("conformance-cli").unwrap();
    let report_path = dir.path().join("report.json");
    let out = repro(&[
        "conformance",
        "--baseline",
        committed_baseline_path().to_str().unwrap(),
        "--serial",
        "--report",
        report_path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let doc = Json::parse(stdout.trim()).unwrap();
    assert_eq!(doc.get("clean").unwrap().as_bool(), Some(true));
    assert_eq!(doc.get("scenarios").unwrap().as_usize(), Some(84));
    assert_eq!(doc.get("bands").unwrap().as_arr().unwrap().len(), 13);
    // The --report artifact is byte-identical to stdout's payload.
    let file = std::fs::read_to_string(&report_path).unwrap();
    assert_eq!(file, stdout.trim());
    // Findings channel carries the PASS summary.
    assert!(String::from_utf8_lossy(&out.stderr).contains("PASS"));
}

#[test]
fn cli_perturbed_baseline_exits_two_with_named_findings() {
    let dir = TempDir::new("conformance-cli-fail").unwrap();
    let path = dir.path().join("perturbed.json");
    let mut base = ConformanceBaseline::load(&committed_baseline_path()).unwrap();
    // An impossible claim ceiling and a shifted band.
    base.claims[0].band.ceiling_pct = 0.01;
    base.grids[0].bands[0].mean_delta_pct += 50.0;
    std::fs::write(&path, base.to_json().emit()).unwrap();
    let out = repro(&["conformance", "--baseline", path.to_str().unwrap(), "--serial"]);
    assert_eq!(out.status.code(), Some(2), "regression must exit 2");
    let doc = Json::parse(String::from_utf8_lossy(&out.stdout).trim()).unwrap();
    assert_eq!(doc.get("clean").unwrap().as_bool(), Some(false));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("BAND REGRESSION"), "{stderr}");
    assert!(stderr.contains("CLAIM REGRESSION"), "{stderr}");
    assert!(stderr.contains("FAIL"), "{stderr}");
}

#[test]
fn cli_write_baseline_then_check_round_trips() {
    let dir = TempDir::new("conformance-cli-write").unwrap();
    let path = dir.path().join("golden.json");
    let out = repro(&["conformance", "--write-baseline", path.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stderr).contains("3 grids"));
    let out = repro(&["conformance", "--baseline", path.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let doc = Json::parse(String::from_utf8_lossy(&out.stdout).trim()).unwrap();
    assert_eq!(doc.get("clean").unwrap().as_bool(), Some(true));
}

#[test]
fn cli_observational_mode_prints_bands() {
    let out = repro(&["conformance", "--serial"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    for needle in [
        "table9",
        "table10",
        "table11",
        "table9_closed_loop",
        "table9_residual",
        "table10_residual",
        "table11_residual",
        "mean Δ %",
        "all",
    ] {
        assert!(stdout.contains(needle), "missing {needle:?} in {stdout}");
    }
}

#[test]
fn cli_closed_loop_check_writes_report_and_exits_zero() {
    let dir = TempDir::new("conformance-cli-cl").unwrap();
    let report_path = dir.path().join("closed_loop_report.json");
    let out = repro(&[
        "conformance",
        "--closed-loop",
        committed_closed_loop_path().to_str().unwrap(),
        "--serial",
        "--closed-loop-report",
        report_path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let doc = Json::parse(stdout.trim()).unwrap();
    assert_eq!(doc.get("clean").unwrap().as_bool(), Some(true));
    assert_eq!(doc.get("scenarios").unwrap().as_usize(), Some(42));
    assert_eq!(doc.get("bands").unwrap().as_arr().unwrap().len(), 6);
    let file = std::fs::read_to_string(&report_path).unwrap();
    assert_eq!(file, stdout.trim());
}

#[test]
fn cli_checks_both_baselines_in_one_invocation() {
    let out = repro(&[
        "conformance",
        "--baseline",
        committed_baseline_path().to_str().unwrap(),
        "--closed-loop",
        committed_closed_loop_path().to_str().unwrap(),
        "--serial",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let doc = Json::parse(String::from_utf8_lossy(&out.stdout).trim()).unwrap();
    assert_eq!(doc.get("kind").unwrap().as_str(), Some("micdl-conformance-run"));
    assert_eq!(doc.get("clean").unwrap().as_bool(), Some(true));
    assert_eq!(
        doc.get("measured").unwrap().get("scenarios").unwrap().as_usize(),
        Some(84)
    );
    assert_eq!(
        doc.get("closed_loop").unwrap().get("scenarios").unwrap().as_usize(),
        Some(42)
    );
}

#[test]
fn cli_report_mirrors_combined_payload_for_both_checks() {
    // --report is the CI artifact hook: whatever check mode puts on
    // stdout (here the combined two-baseline document) lands in the
    // file byte for byte.
    let dir = TempDir::new("conformance-cli-combined-report").unwrap();
    let report_path = dir.path().join("combined.json");
    let out = repro(&[
        "conformance",
        "--baseline",
        committed_baseline_path().to_str().unwrap(),
        "--closed-loop",
        committed_closed_loop_path().to_str().unwrap(),
        "--serial",
        "--report",
        report_path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let file = std::fs::read_to_string(&report_path).unwrap();
    assert_eq!(file, stdout.trim());
    let doc = Json::parse(&file).unwrap();
    assert_eq!(doc.get("kind").unwrap().as_str(), Some("micdl-conformance-run"));
    assert!(doc.get("measured").is_some() && doc.get("closed_loop").is_some());
}

#[test]
fn cli_report_works_with_closed_loop_only() {
    let dir = TempDir::new("conformance-cli-cl-report-only").unwrap();
    let report_path = dir.path().join("cl.json");
    let out = repro(&[
        "conformance",
        "--closed-loop",
        committed_closed_loop_path().to_str().unwrap(),
        "--serial",
        "--report",
        report_path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let doc = Json::parse(&std::fs::read_to_string(&report_path).unwrap()).unwrap();
    assert_eq!(doc.get("kind").unwrap().as_str(), Some("micdl-conformance-report"));
    assert_eq!(doc.get("scenarios").unwrap().as_usize(), Some(42));
}

#[test]
fn cli_perturbed_closed_loop_baseline_exits_two() {
    let dir = TempDir::new("conformance-cli-cl-fail").unwrap();
    let path = dir.path().join("perturbed.json");
    let mut base = ConformanceBaseline::load(&committed_closed_loop_path()).unwrap();
    base.grids[0].bands[0].mean_delta_pct += 50.0;
    std::fs::write(&path, base.to_json().emit()).unwrap();
    let out = repro(&["conformance", "--closed-loop", path.to_str().unwrap(), "--serial"]);
    assert_eq!(out.status.code(), Some(2), "regression must exit 2");
    assert!(String::from_utf8_lossy(&out.stderr).contains("BAND REGRESSION"));
}

#[test]
fn cli_write_closed_loop_then_check_round_trips() {
    let dir = TempDir::new("conformance-cli-cl-write").unwrap();
    let path = dir.path().join("golden.json");
    let out = repro(&["conformance", "--write-closed-loop", path.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stderr).contains("closed-loop baseline"));
    let out = repro(&["conformance", "--closed-loop", path.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let doc = Json::parse(String::from_utf8_lossy(&out.stdout).trim()).unwrap();
    assert_eq!(doc.get("clean").unwrap().as_bool(), Some(true));
}

#[test]
fn cli_residual_check_writes_report_and_exits_zero() {
    let dir = TempDir::new("conformance-cli-res").unwrap();
    let report_path = dir.path().join("residual_smoke_report.json");
    let out = repro(&[
        "conformance",
        "--residual",
        committed_residual_path().to_str().unwrap(),
        "--serial",
        "--residual-report",
        report_path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let doc = Json::parse(stdout.trim()).unwrap();
    assert_eq!(doc.get("clean").unwrap().as_bool(), Some(true));
    assert_eq!(doc.get("scenarios").unwrap().as_usize(), Some(102));
    assert_eq!(doc.get("bands").unwrap().as_arr().unwrap().len(), 14);
    // The --residual-report artifact is byte-identical to stdout.
    let file = std::fs::read_to_string(&report_path).unwrap();
    assert_eq!(file, stdout.trim());
    assert!(String::from_utf8_lossy(&out.stderr).contains("PASS"));
}

#[test]
fn cli_perturbed_residual_baseline_exits_two() {
    let dir = TempDir::new("conformance-cli-res-fail").unwrap();
    let path = dir.path().join("perturbed.json");
    let mut base = ConformanceBaseline::load(&committed_residual_path()).unwrap();
    // A shifted band and an impossible claim ceiling for strategy (c).
    base.grids[0].bands[0].mean_delta_pct += 50.0;
    let c_claim = base
        .claims
        .iter_mut()
        .find(|c| c.strategy == micdl::sweep::Strategy::C)
        .unwrap();
    c_claim.band.ceiling_pct = 0.01;
    std::fs::write(&path, base.to_json().emit()).unwrap();
    let out = repro(&["conformance", "--residual", path.to_str().unwrap(), "--serial"]);
    assert_eq!(out.status.code(), Some(2), "regression must exit 2");
    let doc = Json::parse(String::from_utf8_lossy(&out.stdout).trim()).unwrap();
    assert_eq!(doc.get("clean").unwrap().as_bool(), Some(false));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("BAND REGRESSION"), "{stderr}");
    assert!(stderr.contains("CLAIM REGRESSION"), "{stderr}");
    assert!(stderr.contains("FAIL"), "{stderr}");
}

#[test]
fn cli_write_residual_then_check_round_trips() {
    let dir = TempDir::new("conformance-cli-res-write").unwrap();
    let path = dir.path().join("golden.json");
    let out = repro(&["conformance", "--write-residual", path.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stderr).contains("residual baseline"));
    let out = repro(&["conformance", "--residual", path.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let doc = Json::parse(String::from_utf8_lossy(&out.stdout).trim()).unwrap();
    assert_eq!(doc.get("clean").unwrap().as_bool(), Some(true));
}

#[test]
fn cli_checks_all_three_baselines_in_one_invocation() {
    let out = repro(&[
        "conformance",
        "--baseline",
        committed_baseline_path().to_str().unwrap(),
        "--closed-loop",
        committed_closed_loop_path().to_str().unwrap(),
        "--residual",
        committed_residual_path().to_str().unwrap(),
        "--serial",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let doc = Json::parse(String::from_utf8_lossy(&out.stdout).trim()).unwrap();
    assert_eq!(doc.get("kind").unwrap().as_str(), Some("micdl-conformance-run"));
    assert_eq!(doc.get("clean").unwrap().as_bool(), Some(true));
    assert_eq!(
        doc.get("measured").unwrap().get("scenarios").unwrap().as_usize(),
        Some(84)
    );
    assert_eq!(
        doc.get("closed_loop").unwrap().get("scenarios").unwrap().as_usize(),
        Some(42)
    );
    assert_eq!(
        doc.get("residual").unwrap().get("scenarios").unwrap().as_usize(),
        Some(102)
    );
}

#[test]
fn cli_rejects_unknown_and_conflicting_flags() {
    let out = repro(&["conformance", "--basline", "x.json"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown conformance flag"));
    let out = repro(&["conformance", "--baseline"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("needs a value"));
    let out = repro(&["conformance", "--baseline", "a.json", "--write-baseline", "b.json"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("mutually exclusive"));
    // --report outside check mode would silently write nothing.
    let out = repro(&["conformance", "--report", "out.json"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--report requires"));
    // The closed-loop flags follow the same rules.
    let out = repro(&["conformance", "--closed-loop", "a.json", "--write-closed-loop", "b.json"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("mutually exclusive"));
    let out = repro(&["conformance", "--closed-loop-report", "out.json"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--closed-loop-report requires"));
    // The residual flags follow the same rules.
    let out = repro(&["conformance", "--residual", "a.json", "--write-residual", "b.json"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("mutually exclusive"));
    let out = repro(&["conformance", "--residual-report", "out.json"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--residual-report requires"));
    // Mixing a write mode with a check mode is ambiguous.
    let out = repro(&["conformance", "--baseline", "a.json", "--write-closed-loop", "b.json"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("mutually exclusive"));
    let out = repro(&["conformance", "--residual", "a.json", "--write-baseline", "b.json"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("mutually exclusive"));
}

// ---------------------------------------------------------------------------
// Paper-grid sanity the harness relies on
// ---------------------------------------------------------------------------

#[test]
fn paper_grids_cover_tables_nine_through_eleven() {
    let grids = conformance::paper_grids();
    let sizes: Vec<usize> = grids.iter().map(|(_, g)| g.len()).collect();
    assert_eq!(sizes, vec![42, 24, 18]);
    for (_, grid) in &grids {
        assert!(grid.measure);
    }
}
