#![allow(deprecated)] // the equivalence pins exercise the deprecated constructors

//! Calibration-subsystem integration tests: pre-refactor equivalence
//! (Paper-source predictions are bit-identical to the published-constant
//! closed forms on the Table IX/X/XI grids), closed-loop determinism
//! (ComputedSource across seeds, serial vs parallel), and the tightened
//! strategy-(a) closed-loop band.

use micdl::calibration::{Calibration, Calibrator, ComputedSource, PaperSource};
use micdl::config::{ArchSpec, RunConfig};
use micdl::perfmodel::{model_cpi, ParamSource, PerfModel, StrategyA, StrategyB};
use micdl::report::paper;
use micdl::simulator::SimConfig;
use micdl::sweep::{GridSpec, Strategy, SweepRunner};

/// The old StrategyA paper-constant construction + predict, replicated
/// term for term (the pre-subsystem arithmetic): any reordering inside
/// the calibration path shows up as a bit mismatch.
fn predict_a_paper_reference(arch_idx: usize, run: &RunConfig) -> f64 {
    let machine = micdl::config::MachineConfig::xeon_phi_7120p();
    let s = machine.clock_hz;
    let of = paper::OPERATION_FACTOR[arch_idx];
    let cpi = model_cpi(&machine, run.threads);
    let arch_name = paper::ARCH_NAMES[arch_idx];
    let counts = paper::op_counts(arch_name).unwrap();
    let (f, b) = (counts.fprop.total() as f64, counts.bprop.total() as f64);
    let (i, it, ep) = (
        run.train_images as f64,
        run.test_images as f64,
        run.epochs as f64,
    );
    let chunk_i = i / run.threads as f64;
    let chunk_it = it / run.threads as f64;
    let prep_s = (paper::MODEL_PREP_OPS[arch_idx] * of + 4.0 * i + 2.0 * it + 10.0 * ep) / s;
    let train_s = (f + b + f) * chunk_i * ep * of * cpi / s;
    let test_s = f * chunk_it * ep * of * cpi / s;
    let mem_s = paper::contention_s(arch_name, run.threads).unwrap() * run.epochs as f64
        * run.train_images as f64
        / run.threads as f64;
    prep_s + train_s + test_s + mem_s
}

/// The old StrategyB paper-constant closed form, replicated term for
/// term.
fn predict_b_paper_reference(arch_idx: usize, run: &RunConfig) -> f64 {
    let machine = micdl::config::MachineConfig::xeon_phi_7120p();
    let cpi = model_cpi(&machine, run.threads);
    let ep = run.epochs as f64;
    let chunk_i = run.train_images as f64 / run.threads as f64;
    let chunk_it = run.test_images as f64 / run.threads as f64;
    let (tf, tb) = (paper::T_FPROP_S[arch_idx], paper::T_BPROP_S[arch_idx]);
    let prep_s = paper::T_PREP_S[arch_idx];
    let train_s = (tf + tb + tf) * chunk_i * ep * cpi;
    let test_s = tf * chunk_it * ep * cpi;
    let mem_s = paper::contention_s(paper::ARCH_NAMES[arch_idx], run.threads).unwrap()
        * run.epochs as f64
        * run.train_images as f64
        / run.threads as f64;
    prep_s + train_s + test_s + mem_s
}

/// Every workload of the Table IX, X and XI evaluation grids, per
/// architecture index.
fn paper_grid_runs(arch_idx: usize) -> Vec<RunConfig> {
    let name = paper::ARCH_NAMES[arch_idx];
    let mut runs = Vec::new();
    // Table IX: the measured domain.
    for &p in &RunConfig::MEASURED_THREADS {
        runs.push(RunConfig::paper_default(name, p));
    }
    // Table X: the extrapolation thread counts.
    for &p in &paper::TABLE10_THREADS {
        runs.push(RunConfig::paper_default(name, p));
    }
    // Table XI: workload scaling (defined on the small CNN).
    if name == "small" {
        for &(i, it) in &paper::TABLE11_IMAGES {
            for &ep in &paper::TABLE11_EPOCHS {
                for &p in &paper::TABLE11_THREADS {
                    runs.push(RunConfig {
                        train_images: i,
                        test_images: it,
                        epochs: ep,
                        threads: p,
                    });
                }
            }
        }
    }
    runs
}

#[test]
fn paper_source_predictions_bit_identical_on_paper_grids() {
    // The acceptance pin: ParamSource::Paper routed through the new
    // calibration subsystem reproduces the pre-refactor published-
    // constant closed forms bit for bit over Tables IX, X and XI.
    for (idx, arch) in ArchSpec::paper_archs().iter().enumerate() {
        let a = StrategyA::new(arch, ParamSource::Paper).unwrap();
        let b = StrategyB::new(arch, ParamSource::Paper).unwrap();
        for run in paper_grid_runs(idx) {
            let got_a = a.predict(&run).unwrap().total_s;
            let want_a = predict_a_paper_reference(idx, &run);
            assert_eq!(
                got_a.to_bits(),
                want_a.to_bits(),
                "{} (a) p={} i={}: {got_a} vs {want_a}",
                arch.name,
                run.threads,
                run.train_images
            );
            let got_b = b.predict(&run).unwrap().total_s;
            let want_b = predict_b_paper_reference(idx, &run);
            assert_eq!(
                got_b.to_bits(),
                want_b.to_bits(),
                "{} (b) p={} i={}: {got_b} vs {want_b}",
                arch.name,
                run.threads,
                run.train_images
            );
        }
    }
}

#[test]
fn paper_source_params_equal_published_tables() {
    let sim = SimConfig::default();
    for (i, arch) in ArchSpec::paper_archs().iter().enumerate() {
        let params = PaperSource.resolve(arch, &sim).unwrap();
        let a = params.strategy_a().unwrap();
        assert_eq!(a.operation_factor.to_bits(), paper::OPERATION_FACTOR[i].to_bits());
        assert_eq!(a.prep_ops.to_bits(), paper::MODEL_PREP_OPS[i].to_bits());
        let b = params.strategy_b().unwrap();
        assert_eq!(b.t_fprop_s.to_bits(), paper::T_FPROP_S[i].to_bits());
        assert_eq!(b.t_bprop_s.to_bits(), paper::T_BPROP_S[i].to_bits());
        assert_eq!(b.t_prep_s.to_bits(), paper::T_PREP_S[i].to_bits());
    }
}

#[test]
fn computed_source_deterministic_across_seeds_and_worker_counts() {
    // The fit depends only on genuine simulator constants: a reseeded
    // configuration resolves bit-identical strategy-(a) parameters, and
    // the whole closed-loop grid is bit-identical parallel vs serial.
    let arch = ArchSpec::medium();
    let base = ComputedSource
        .resolve(&arch, &SimConfig::default())
        .unwrap()
        .strategy_a()
        .unwrap();
    for seed in [1u64, 0xDEAD_BEEF, 1 << 40] {
        let sim = SimConfig { seed, ..SimConfig::default() };
        let again = ComputedSource.resolve(&arch, &sim).unwrap().strategy_a().unwrap();
        assert_eq!(base.operation_factor.to_bits(), again.operation_factor.to_bits());
        assert_eq!(base.prep_ops.to_bits(), again.prep_ops.to_bits());
        assert_eq!(base.fprop_ops.to_bits(), again.fprop_ops.to_bits());
    }
    let grid = GridSpec::table9_closed_loop();
    let serial = SweepRunner::serial().run(&grid).unwrap();
    let parallel = SweepRunner::new(4).run(&grid).unwrap();
    for (s, p) in serial.results.iter().zip(parallel.results.iter()) {
        assert_eq!(s.prediction.total_s.to_bits(), p.prediction.total_s.to_bits());
        assert_eq!(
            s.measured_s.unwrap().to_bits(),
            p.measured_s.unwrap().to_bits()
        );
    }
}

#[test]
fn closed_loop_strategy_a_band_tightens_to_structural_percent() {
    // The tentpole payoff: with the ComputedSource fit, strategy (a)'s
    // closed-loop medium-CNN band drops from the documented ~58 %
    // (computed-vs-paper op-count gap) to the structural few percent.
    let res = SweepRunner::new(0).run(&GridSpec::table9_closed_loop()).unwrap();
    let medium_a = res.accuracy_for("medium", Strategy::A).unwrap();
    assert!(
        medium_a.mean_delta_pct < 10.0,
        "medium/a closed-loop mean Δ = {:.2}% (pre-calibration: ~58%)",
        medium_a.mean_delta_pct
    );
    // Every (a) group sits in single digits now.
    for arch in ["small", "medium", "large"] {
        let g = res.accuracy_for(arch, Strategy::A).unwrap();
        assert!(g.mean_delta_pct < 10.0, "{arch}/a: {:.2}%", g.mean_delta_pct);
    }
    // And the closed loop beats the open loop (paper parameters) for
    // strategy (a) overall.
    let open = SweepRunner::new(0).run(&GridSpec::table9()).unwrap();
    let closed_a = res.accuracy_overall(Strategy::A).unwrap().mean_delta_pct;
    let open_a = open.accuracy_overall(Strategy::A).unwrap().mean_delta_pct;
    assert!(closed_a < open_a, "closed {closed_a:.2}% !< open {open_a:.2}%");
}

#[test]
fn calibration_facade_memoizes_across_strategy_constructions() {
    // Resolving twice (as the a/b pair of a sweep cell does) runs the
    // calibrator once; models built from the shared params agree with
    // the direct constructors bit for bit.
    let cal = Calibration::new(ParamSource::Simulator);
    let arch = ArchSpec::small();
    let sim = SimConfig::default();
    let params = cal.resolve(&arch, &sim).unwrap();
    let params_again = cal.resolve(&arch, &sim).unwrap();
    assert_eq!(cal.resolutions(), 1);
    let a = StrategyA::from_params(&params).unwrap();
    let b = StrategyB::from_params(&params_again).unwrap();
    let direct_a = StrategyA::with_sim(&arch, ParamSource::Simulator, &sim).unwrap();
    let direct_b = StrategyB::with_sim(&arch, ParamSource::Simulator, &sim).unwrap();
    let run = RunConfig::paper_default("small", 240);
    assert_eq!(
        a.predict(&run).unwrap().total_s.to_bits(),
        direct_a.predict(&run).unwrap().total_s.to_bits()
    );
    assert_eq!(
        b.predict(&run).unwrap().total_s.to_bits(),
        direct_b.predict(&run).unwrap().total_s.to_bits()
    );
}

#[test]
fn param_source_op_source_routing_matches_resolved_counts() {
    // The satellite pin: the ParamSource → OpSource mapping lives in one
    // place and the calibrators route through it — Simulator resolves
    // computed counts, Paper resolves the published tables.
    use micdl::nn::opcount;
    let arch = ArchSpec::small();
    let sim = SimConfig::default();
    let computed = ComputedSource.resolve(&arch, &sim).unwrap().strategy_a().unwrap();
    let counts = opcount::resolve(&arch, ParamSource::Simulator.op_source()).unwrap();
    assert_eq!(computed.fprop_ops, counts.fprop.total() as f64);
    let paper_params = PaperSource.resolve(&arch, &sim).unwrap().strategy_a().unwrap();
    let paper_counts = opcount::resolve(&arch, ParamSource::Paper.op_source()).unwrap();
    assert_eq!(paper_params.fprop_ops, paper_counts.fprop.total() as f64);
    assert_ne!(computed.fprop_ops, paper_params.fprop_ops);
}

#[test]
fn kfold_held_out_residual_gate() {
    // The cross-validation gate on strategy (c): the ridge fit must
    // generalise, not memorise its 44-point training grid. For every
    // paper architecture, the k-fold held-out mean Δ stays within a
    // tolerance of the in-sample mean Δ, and both stay below the raw
    // strategy-(b) band on the same grid.
    //
    // Because the training target is z = ln(measured / predicted_b),
    // measured = pred_b·e^z, so the per-point deltas fall out of the
    // samples alone: Δ_b = |e^z − 1| and Δ_c = |e^(z − w·x) − 1| — no
    // re-simulation needed.
    use micdl::calibration::residual;
    const K: usize = 4;
    const KFOLD_TOL_PP: f64 = 3.0;
    let mean = |ds: &[f64]| ds.iter().sum::<f64>() / ds.len() as f64;
    let delta_c = |s: &residual::TrainSample, w: &[f64]| {
        let wx: f64 = s.features.iter().zip(w).map(|(x, wi)| x * wi).sum();
        ((s.z - wx).exp() - 1.0).abs() * 100.0
    };
    for arch in ArchSpec::paper_archs() {
        let b = StrategyB::new(&arch, ParamSource::Paper).unwrap();
        let samples = residual::training_samples(&arch, &b, &SimConfig::default()).unwrap();
        assert_eq!(samples.len(), 44, "{}: training grid size", arch.name);
        let b_mean = mean(
            &samples
                .iter()
                .map(|s| (s.z.exp() - 1.0).abs() * 100.0)
                .collect::<Vec<_>>(),
        );
        // In-sample: fit on the whole grid, score the whole grid.
        let all: Vec<(Vec<f64>, f64)> =
            samples.iter().map(|s| (s.features.clone(), s.z)).collect();
        let w = residual::solve(&all, residual::LAMBDA).unwrap();
        let in_sample = mean(&samples.iter().map(|s| delta_c(s, &w)).collect::<Vec<_>>());
        // Held-out: fold i holds out every sample with index ≡ i (mod K)
        // and scores it with the model fitted on the rest.
        let mut held = Vec::new();
        for fold in 0..K {
            let train: Vec<(Vec<f64>, f64)> = samples
                .iter()
                .enumerate()
                .filter(|(i, _)| i % K != fold)
                .map(|(_, s)| (s.features.clone(), s.z))
                .collect();
            let wf = residual::solve(&train, residual::LAMBDA).unwrap();
            held.extend(
                samples
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % K == fold)
                    .map(|(_, s)| delta_c(s, &wf)),
            );
        }
        assert_eq!(held.len(), samples.len(), "{}: every point held out once", arch.name);
        let held_out = mean(&held);
        assert!(
            held_out <= in_sample + KFOLD_TOL_PP,
            "{}: held-out mean Δ {held_out:.3}% exceeds in-sample {in_sample:.3}% + {KFOLD_TOL_PP} pp",
            arch.name
        );
        assert!(
            held_out < b_mean,
            "{}: held-out (c) mean Δ {held_out:.3}% must beat raw (b) {b_mean:.3}%",
            arch.name
        );
        assert!(
            in_sample < b_mean,
            "{}: in-sample (c) mean Δ {in_sample:.3}% must beat raw (b) {b_mean:.3}%",
            arch.name
        );
    }
}
