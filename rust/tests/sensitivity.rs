//! Sensitivity-report integration tests: ranked ∂Δ/∂constant output,
//! bit-identical parallel vs serial, ranking stability across runs
//! (cache hits vs cold), cache efficiency vs a plain ablation sweep,
//! and the `repro sensitivity` CLI.

use std::process::{Command, Output};

use micdl::config::ArchSpec;
use micdl::simulator::SimConfig;
use micdl::sweep::{sensitivity, SensitivitySpec, SimConstant, Strategy, SweepRunner};
use micdl::util::json::Json;
use micdl::util::tmp::TempDir;

fn repro(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .output()
        .expect("spawn repro")
}

fn acceptance_spec() -> SensitivitySpec {
    // The acceptance criterion's domain: --arch small,medium, both
    // strategies, the full constant set.
    SensitivitySpec {
        archs: vec![ArchSpec::small(), ArchSpec::medium()],
        ..SensitivitySpec::default()
    }
}

#[test]
fn ranked_report_is_bit_identical_parallel_vs_serial() {
    let spec = acceptance_spec();
    let serial = sensitivity::run(&spec, &SweepRunner::serial()).unwrap();
    let parallel = sensitivity::run(&spec, &SweepRunner::new(4)).unwrap();
    // The machine-readable payload is the acceptance surface: identical
    // bytes regardless of worker count or scheduling.
    assert_eq!(serial.to_json().emit(), parallel.to_json().emit());
    // Ranked and populated: every constant ranked, every (constant ×
    // arch × strategy) group reported.
    assert_eq!(serial.ranking.len(), SimConstant::ALL.len());
    assert_eq!(serial.entries.len(), SimConstant::ALL.len() * 2 * 2);
    assert!(serial.ranking[0].mean_abs_gradient > 0.0, "empty ranking");
    assert!(
        serial
            .ranking
            .windows(2)
            .all(|w| w[0].mean_abs_gradient >= w[1].mean_abs_gradient),
        "ranking must be descending"
    );
}

#[test]
fn rankings_stable_across_cold_and_warm_runs() {
    // Two cold runs agree bit for bit (all folds deterministic), and a
    // single run's internal cache reuse (base + 16 perturbed variants
    // share per-variant entries between the a/b rows) cannot perturb
    // the ranking: the memoized values are bit-identical to fresh
    // computation by construction, asserted via the repeat run.
    let spec = SensitivitySpec {
        archs: vec![ArchSpec::small()],
        threads: vec![15, 240],
        ..SensitivitySpec::default()
    };
    let runner = SweepRunner::serial();
    let first = sensitivity::run(&spec, &runner).unwrap();
    let second = sensitivity::run(&spec, &runner).unwrap();
    assert_eq!(first.to_json().emit(), second.to_json().emit());
    let order_a: Vec<&str> = first.ranking.iter().map(|r| r.constant.key()).collect();
    let order_b: Vec<&str> = second.ranking.iter().map(|r| r.constant.key()).collect();
    assert_eq!(order_a, order_b);
}

#[test]
fn cache_hit_rate_at_least_plain_ablation_sweeps() {
    // The sensitivity analysis rides the same fingerprint-keyed cache as
    // a hand-built `repro sweep --sim-*` ablation over the identical
    // variant set: its hit rate must not regress below that path's.
    let spec = SensitivitySpec {
        archs: vec![ArchSpec::small()],
        threads: vec![15, 240],
        ..SensitivitySpec::default()
    };
    let grid = spec.to_grid(&SimConfig::default()).unwrap();
    let plain = SweepRunner::serial().run(&grid).unwrap();
    let report = sensitivity::run(&spec, &SweepRunner::serial()).unwrap();
    assert!(
        report.cache.hit_rate() >= plain.cache.hit_rate(),
        "sensitivity {:.3} < plain ablation {:.3}",
        report.cache.hit_rate(),
        plain.cache.hit_rate()
    );
    assert!(report.cache.hits > 0, "ablation grid must share cache entries");
}

#[test]
fn closed_loop_sensitivity_recalibrates_per_variant() {
    // Under --params sim the models re-fit against every perturbed
    // variant, so cycle-constant perturbations are largely absorbed
    // (the fit tracks them) while under --params paper they hit the
    // measured side at full strength: the paper-params gradient for
    // fwd_cycles_per_op must exceed the closed-loop one.
    let base = SensitivitySpec {
        archs: vec![ArchSpec::small()],
        threads: vec![15, 240],
        strategies: vec![Strategy::B],
        constants: vec![SimConstant::FwdCyclesPerOp],
        ..SensitivitySpec::default()
    };
    let open = sensitivity::run(&base, &SweepRunner::serial()).unwrap();
    let closed_spec = SensitivitySpec {
        params: micdl::perfmodel::ParamSource::Simulator,
        ..base
    };
    let closed = sensitivity::run(&closed_spec, &SweepRunner::serial()).unwrap();
    let g_open = open.entries[0].gradient_pp_per_pct.abs();
    let g_closed = closed.entries[0].gradient_pp_per_pct.abs();
    assert!(
        g_closed < g_open,
        "closed loop must absorb the constant: {g_closed} !< {g_open}"
    );
}

// ---------------------------------------------------------------------------
// CLI level (the acceptance path)
// ---------------------------------------------------------------------------

#[test]
fn cli_sensitivity_writes_ranked_json_report() {
    let dir = TempDir::new("sensitivity-cli").unwrap();
    let path = dir.path().join("out.json");
    let out = repro(&[
        "sensitivity",
        "--arch",
        "small",
        "--threads",
        "15,240",
        "--serial",
        "--json",
        path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("sensitivity ranking"), "{stdout}");
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(doc.get("kind").unwrap().as_str(), Some("micdl-sensitivity-report"));
    let ranking = doc.get("ranking").unwrap().as_arr().unwrap();
    assert_eq!(ranking.len(), SimConstant::ALL.len());
    assert!(doc.get("entries").unwrap().as_arr().unwrap().len() >= ranking.len());
    assert_eq!(doc.get("params").unwrap().as_str(), Some("paper"));
}

#[test]
fn cli_sensitivity_constant_subset_and_step() {
    let out = repro(&[
        "sensitivity",
        "--arch",
        "small",
        "--threads",
        "15",
        "--constants",
        "clock_ghz,ring_beta",
        "--step",
        "0.05",
        "--serial",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("clock_ghz") && stdout.contains("ring_beta"), "{stdout}");
    assert!(stdout.contains("±5%"), "{stdout}");
    assert!(!stdout.contains("l2_alpha"), "{stdout}");
}

#[test]
fn cli_sensitivity_rejects_bad_flags() {
    let out = repro(&["sensitivity", "--archs", "small"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown sensitivity flag"));
    let out = repro(&["sensitivity", "--step"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("needs a value"));
    let out = repro(&["sensitivity", "--constants", "l2alpha"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown sim constant"));
    let out = repro(&["sensitivity", "--step", "2.0"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("step"));
}
