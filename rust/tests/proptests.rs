//! Property-based tests over randomized inputs.
//!
//! The proptest crate is unavailable in this offline build, so the same
//! discipline is implemented directly: a seeded generator drives many
//! randomized cases per property, and failures print the offending seed
//! so the case replays deterministically.

use micdl::config::{ArchSpec, LayerSpec, MachineConfig, RunConfig};
use micdl::coordinator::shard::Shard;
use micdl::nn::init::XorShift64;
use micdl::nn::opcount;
use micdl::perfmodel::accuracy::{average_delta, delta_series};
use micdl::perfmodel::{both_models, delta_pct, DeltaAccumulator, ParamSource, PerfModel};
use micdl::report::paper;
use micdl::simulator::{simulate_training, workload, Fidelity, SimConfig};
use micdl::util::json::Json;

const CASES: usize = 200;

// ---------------------------------------------------------------------------
// Sharding / chunking invariants (coordinator state & routing)
// ---------------------------------------------------------------------------

#[test]
fn prop_shards_partition_disjointly_and_conserve() {
    let mut rng = XorShift64::new(101);
    for case in 0..CASES {
        let n = rng.next_below(100_000);
        let p = 1 + rng.next_below(512);
        let shards = Shard::all(n, p);
        let mut covered = 0usize;
        for (t, s) in shards.iter().enumerate() {
            assert!(s.start <= s.end, "case {case}: t={t}");
            if t > 0 {
                assert_eq!(shards[t - 1].end, s.start, "case {case}: gap/overlap");
            }
            covered += s.len();
        }
        assert_eq!(covered, n, "case {case}: n={n} p={p}");
        // Balance: sizes differ by at most one.
        let max = shards.iter().map(Shard::len).max().unwrap();
        let min = shards.iter().map(Shard::len).min().unwrap();
        assert!(max - min <= 1, "case {case}");
        // Agreement with the simulator's chunk arithmetic.
        for (t, s) in shards.iter().enumerate() {
            assert_eq!(s.len(), workload::chunk_of(n, p, t), "case {case} t={t}");
        }
    }
}

#[test]
fn prop_shard_matches_workload_chunk_mapping() {
    // coordinator::shard and the simulator's ⌈i/p⌉/⌊i/p⌋ mapping must be
    // the same partition: exhaustively for every (images, p) pair up to
    // 64×64, then on randomized large pairs.
    for n in 0..=64usize {
        for p in 1..=64usize {
            let shards = Shard::all(n, p);
            for (t, s) in shards.iter().enumerate() {
                let want = if t < n % p { n / p + 1 } else { n / p };
                assert_eq!(s.len(), want, "n={n} p={p} t={t}");
                assert_eq!(s.len(), workload::chunk_of(n, p, t), "n={n} p={p} t={t}");
            }
            if n > 0 {
                // The slowest worker's share is ⌈n/p⌉ — what the models
                // fold into their chunk terms (RunConfig::train_chunk).
                let rc = RunConfig {
                    train_images: n,
                    test_images: 0,
                    epochs: 1,
                    threads: p,
                };
                assert_eq!(rc.train_chunk(), shards[0].len(), "n={n} p={p}");
            }
        }
    }
    let mut rng = XorShift64::new(1616);
    for case in 0..CASES {
        let n = rng.next_below(1_000_000);
        let p = 1 + rng.next_below(4_096);
        let t = rng.next_below(p);
        assert_eq!(
            Shard::of(n, p, t).len(),
            workload::chunk_of(n, p, t),
            "case {case}: n={n} p={p} t={t}"
        );
        // Boundary workers carry the ceiling and floor shares.
        let first = Shard::of(n, p, 0).len();
        assert_eq!(first, if n % p > 0 { n / p + 1 } else { n / p }, "case {case}");
        assert_eq!(Shard::of(n, p, p - 1).len(), n / p, "case {case}");
    }
}

// ---------------------------------------------------------------------------
// Machine placement invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_occupancy_counts_are_consistent() {
    let mut rng = XorShift64::new(202);
    let m = MachineConfig::xeon_phi_7120p();
    for case in 0..CASES {
        let p = 1 + rng.next_below(4096);
        let machine = micdl::simulator::PhiMachine::new(m.clone(), p);
        // Sum of software threads across cores equals p.
        let mut total = 0usize;
        for core in 0..m.cores.min(p) {
            total += machine.sw_threads_on_core(core);
        }
        assert_eq!(total, p, "case {case}: p={p}");
        // Occupancy never exceeds the SMT width; oversub ≥ 1.
        for t in [0, p / 2, p - 1] {
            assert!(machine.occupancy_of(t) <= m.threads_per_core);
            assert!(machine.oversub_of(t) >= 1.0);
        }
    }
}

// ---------------------------------------------------------------------------
// Simulator monotonicity / linearity
// ---------------------------------------------------------------------------

#[test]
fn prop_sim_time_monotone_in_epochs_and_images() {
    let mut rng = XorShift64::new(303);
    let cfg = SimConfig::default();
    let arch = ArchSpec::small();
    for case in 0..40 {
        let base = RunConfig {
            train_images: 100 + rng.next_below(5_000),
            test_images: rng.next_below(1_000),
            epochs: 1 + rng.next_below(10),
            threads: 1 + rng.next_below(300),
        };
        let t0 = simulate_training(&arch, &base, &cfg).unwrap().execution_s;
        let more_ep = base.with_epochs(base.epochs + 1 + rng.next_below(5));
        let t1 = simulate_training(&arch, &more_ep, &cfg).unwrap().execution_s;
        assert!(t1 > t0, "case {case}: epochs up, time down? {base:?}");
        let more_imgs = RunConfig {
            train_images: base.train_images * 2,
            ..base
        };
        let t2 = simulate_training(&arch, &more_imgs, &cfg).unwrap().execution_s;
        assert!(t2 > t0, "case {case}: images up, time down? {base:?}");
    }
}

#[test]
fn prop_sim_execution_linear_in_epochs() {
    // execution (prep excluded) must scale exactly linearly with ep in
    // chunked mode.
    let mut rng = XorShift64::new(404);
    let cfg = SimConfig::default();
    let arch = ArchSpec::medium();
    for case in 0..40 {
        let run = RunConfig {
            train_images: 500 + rng.next_below(3_000),
            test_images: rng.next_below(500),
            epochs: 1 + rng.next_below(6),
            threads: 1 + rng.next_below(244),
        };
        let t1 = simulate_training(&arch, &run, &cfg).unwrap().execution_s;
        let t3 = simulate_training(&arch, &run.with_epochs(run.epochs * 3), &cfg)
            .unwrap()
            .execution_s;
        let ratio = t3 / t1;
        assert!((ratio - 3.0).abs() < 1e-9, "case {case}: {ratio} {run:?}");
    }
}

#[test]
fn prop_fidelity_modes_agree_across_random_sim_configs() {
    // The simulator docs claim PerImage ≡ Chunked to float tolerance for
    // *any* configuration; here the whole SimConfig is randomized, not
    // just the workload. Generation keeps the physical preconditions of
    // the chunked window argument: a non-decreasing CPI ladder and
    // non-negative coefficients, so per-image cost is non-decreasing in
    // (occupancy, oversubscription) and thread 0 stays the slowest.
    // Oversubscription (p up to 2× hardware capacity) is included.
    let mut rng = XorShift64::new(1515);
    for case in 0..30 {
        let mut cfg = SimConfig::default();
        cfg.machine.cores = 1 + rng.next_below(96);
        cfg.machine.threads_per_core = 1 + rng.next_below(6);
        cfg.machine.clock_hz = 0.6e9 + rng.next_below(4) as f64 * 0.5e9;
        let mut cpi = 1.0 + rng.next_below(3) as f64 * 0.25;
        cfg.machine.cpi_ladder = (0..cfg.machine.threads_per_core)
            .map(|_| {
                cpi += rng.next_below(3) as f64 * 0.25;
                cpi
            })
            .collect();
        cfg.fwd_cycles_per_op = 5.0 + rng.next_below(60) as f64;
        cfg.bwd_cycles_per_op = 5.0 + rng.next_below(30) as f64;
        cfg.exec_fraction = 0.3 + rng.next_below(8) as f64 * 0.1;
        cfg.l2_alpha = rng.next_below(100) as f64 * 0.01;
        cfg.l2_ratio_cap = 0.5 + rng.next_below(6) as f64;
        cfg.ring_beta = rng.next_below(60) as f64 * 0.01;
        cfg.oversub_overhead = rng.next_below(20) as f64 * 0.01;
        cfg.prep_io_s = rng.next_below(20) as f64;
        cfg.prep_cycles_per_weight = 1.0 + rng.next_below(30) as f64;
        cfg.serial_cycles_per_image = rng.next_below(10) as f64;
        cfg.seed = rng.next_below(1 << 30) as u64;
        let cap = cfg.machine.cores * cfg.machine.threads_per_core;
        let run = RunConfig {
            train_images: 1 + rng.next_below(300),
            test_images: rng.next_below(80),
            epochs: 1 + rng.next_below(3),
            threads: 1 + rng.next_below(cap * 2),
        };
        let arch = ArchSpec::paper_archs()[case % 3].clone();

        let mut chunked_cfg = cfg.clone();
        chunked_cfg.fidelity = Fidelity::Chunked;
        let a = simulate_training(&arch, &run, &chunked_cfg)
            .unwrap_or_else(|e| panic!("case {case}: {e} ({run:?})"));
        let mut image_cfg = cfg.clone();
        image_cfg.fidelity = Fidelity::PerImage;
        let b = simulate_training(&arch, &run, &image_cfg).unwrap();
        assert!(
            (a.total_s - b.total_s).abs() / b.total_s < 1e-9,
            "case {case}: chunked {} vs per-image {} (cfg={cfg:?} run={run:?})",
            a.total_s,
            b.total_s
        );
        assert!(b.events > 0 && a.events == 0, "case {case}");

        // Determinism + seed-stability of the measured path: an
        // identical config replays bit-for-bit, and a config differing
        // only in seed produces bit-identical times too (the seed feeds
        // the cache fingerprint, not the arithmetic).
        let replay = simulate_training(&arch, &run, &chunked_cfg).unwrap();
        assert_eq!(replay.total_s.to_bits(), a.total_s.to_bits(), "case {case}");
        let mut reseeded = chunked_cfg.clone();
        reseeded.seed ^= 0x5EED_F00D;
        assert_ne!(reseeded.fingerprint(), chunked_cfg.fingerprint());
        let c = simulate_training(&arch, &run, &reseeded).unwrap();
        assert_eq!(c.total_s.to_bits(), a.total_s.to_bits(), "case {case}");
    }
}

#[test]
fn prop_fidelity_modes_agree_on_random_workloads() {
    let mut rng = XorShift64::new(505);
    let chunked = SimConfig { fidelity: Fidelity::Chunked, ..Default::default() };
    let image = SimConfig { fidelity: Fidelity::PerImage, ..Default::default() };
    for case in 0..25 {
        let run = RunConfig {
            train_images: 1 + rng.next_below(400),
            test_images: rng.next_below(100),
            epochs: 1 + rng.next_below(3),
            threads: 1 + rng.next_below(128),
        };
        let arch = match case % 3 {
            0 => ArchSpec::small(),
            1 => ArchSpec::medium(),
            _ => ArchSpec::large(),
        };
        let a = simulate_training(&arch, &run, &chunked).unwrap().total_s;
        let b = simulate_training(&arch, &run, &image).unwrap().total_s;
        assert!(
            (a - b).abs() / b < 1e-9,
            "case {case}: chunked {a} vs per-image {b} ({run:?})"
        );
    }
}

// ---------------------------------------------------------------------------
// Model properties
// ---------------------------------------------------------------------------

#[test]
fn prop_models_monotone_in_workload() {
    let mut rng = XorShift64::new(606);
    for case in 0..60 {
        let arch = ArchSpec::paper_archs()[case % 3].clone();
        let (a, b) = both_models(&arch, ParamSource::Paper).unwrap();
        let run = RunConfig {
            train_images: 1_000 + rng.next_below(100_000),
            test_images: 100 + rng.next_below(10_000),
            epochs: 1 + rng.next_below(100),
            threads: 1 + rng.next_below(3_840),
        };
        for model in [&a as &dyn PerfModel, &b as &dyn PerfModel] {
            let t = model.predict(&run).unwrap().total_s;
            assert!(t > 0.0 && t.is_finite());
            let bigger = RunConfig {
                train_images: run.train_images + 1_000,
                ..run
            };
            let t2 = model.predict(&bigger).unwrap().total_s;
            assert!(t2 > t, "case {case} model {}", model.name());
        }
    }
}

#[test]
fn prop_model_b_total_decomposes_exactly() {
    let mut rng = XorShift64::new(707);
    let arch = ArchSpec::large();
    let (_, b) = both_models(&arch, ParamSource::Paper).unwrap();
    for _ in 0..CASES {
        let run = RunConfig {
            train_images: 1 + rng.next_below(200_000),
            test_images: 1 + rng.next_below(20_000),
            epochs: 1 + rng.next_below(300),
            threads: 1 + rng.next_below(4_000),
        };
        let p = b.predict(&run).unwrap();
        let sum = p.prep_s + p.train_s + p.test_s + p.mem_s;
        assert!((p.total_s - sum).abs() < 1e-6 * p.total_s.max(1.0));
    }
}

// ---------------------------------------------------------------------------
// Accuracy layer (Δ) properties
// ---------------------------------------------------------------------------

#[test]
fn prop_delta_pct_nonnegative_and_symmetric_under_abs() {
    // Δ = |m − p| / p · 100: non-negative, zero iff m == p, and symmetric
    // in the sign of the error (p+d and p−d give bit-identical Δ).
    // Integer-valued inputs keep p±d and the differences exactly
    // representable, so the symmetry really is an |·| property and not a
    // rounding accident (fl(p+d)−p and p−fl(p−d) can differ in the last
    // ulp for arbitrary reals).
    let mut rng = XorShift64::new(1313);
    for case in 0..CASES {
        let predicted = (1 + rng.next_below(1_000_000)) as f64;
        let err = rng.next_below(1_000_000) as f64;
        let over = delta_pct(predicted + err, predicted);
        let under = delta_pct(predicted - err, predicted);
        assert!(over >= 0.0 && under >= 0.0, "case {case}");
        assert_eq!(
            over.to_bits(),
            under.to_bits(),
            "case {case}: Δ(p+d) {over} != Δ(p−d) {under}"
        );
        assert_eq!(delta_pct(predicted, predicted), 0.0, "case {case}");
        if err > 0.0 {
            assert!(over > 0.0, "case {case}: nonzero error gave Δ = 0");
        }
    }
}

#[test]
fn prop_average_delta_is_mean_of_delta_series() {
    // The aggregate must equal the mean of the per-point series it
    // summarizes — same points, same order, bit-for-bit.
    let mut rng = XorShift64::new(1414);
    let cfg = SimConfig::default();
    for case in 0..12 {
        let arch = ArchSpec::paper_archs()[case % 3].clone();
        let (a, b) = both_models(&arch, ParamSource::Paper).unwrap();
        // A random non-empty subset of plausible thread counts.
        let mut threads: Vec<usize> = Vec::new();
        for &p in &[1usize, 15, 30, 60, 120, 180, 240, 480] {
            if rng.next_below(2) == 0 {
                threads.push(p);
            }
        }
        threads.push(1 + rng.next_below(3_840));
        for model in [&a as &dyn PerfModel, &b as &dyn PerfModel] {
            let avg = average_delta(&arch, model, &threads, &cfg).unwrap();
            let series = delta_series(&arch, model, &threads, &cfg).unwrap();
            assert_eq!(series.len(), threads.len(), "case {case}");
            let mean = series.iter().map(|&(_, d)| d).sum::<f64>() / threads.len() as f64;
            assert_eq!(
                avg.to_bits(),
                mean.to_bits(),
                "case {case} model {}: {avg} != {mean}",
                model.name()
            );
            // And folding the series through the sweep accumulator gives
            // the same mean again, with the max at one of the points.
            let mut acc = DeltaAccumulator::default();
            for &(p, d) in &series {
                assert!(d >= 0.0 && d.is_finite(), "case {case} p={p}");
                acc.push(d, p);
            }
            assert_eq!(acc.mean_pct().unwrap().to_bits(), avg.to_bits());
            let (max, max_at) = acc.max_pct().unwrap();
            assert!(series.iter().any(|&(p, d)| p == max_at && d == max));
            assert!(series.iter().all(|&(_, d)| d <= max));
        }
    }
}

// ---------------------------------------------------------------------------
// Contention table properties
// ---------------------------------------------------------------------------

#[test]
fn prop_paper_contention_monotone_in_threads() {
    let mut rng = XorShift64::new(808);
    for arch in ["small", "medium", "large"] {
        for _ in 0..CASES {
            let p1 = 1 + rng.next_below(5_000);
            let p2 = p1 + 1 + rng.next_below(1_000);
            let c1 = paper::contention_s(arch, p1).unwrap();
            let c2 = paper::contention_s(arch, p2).unwrap();
            assert!(c2 >= c1, "{arch}: contention({p2}) < contention({p1})");
        }
    }
}

// ---------------------------------------------------------------------------
// JSON roundtrip fuzz
// ---------------------------------------------------------------------------

fn random_json(rng: &mut XorShift64, depth: usize) -> Json {
    match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.next_below(2) == 0),
        2 => Json::Num((rng.next_below(2_000_000) as f64 - 1e6) / 97.0),
        3 => {
            let len = rng.next_below(12);
            let s: String = (0..len)
                .map(|_| {
                    let c = rng.next_below(96) as u8 + 32;
                    c as char
                })
                .collect();
            Json::Str(s)
        }
        4 => {
            let len = rng.next_below(5);
            Json::Arr((0..len).map(|_| random_json(rng, depth - 1)).collect())
        }
        _ => {
            let len = rng.next_below(5);
            Json::Obj(
                (0..len)
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            )
        }
    }
}

#[test]
fn prop_json_emit_parse_roundtrip() {
    let mut rng = XorShift64::new(909);
    for case in 0..CASES {
        let v = random_json(&mut rng, 3);
        let text = v.emit();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, v, "case {case}: {text}");
    }
}

// ---------------------------------------------------------------------------
// Architecture generator: valid stacks always shape-check, op counts grow
// ---------------------------------------------------------------------------

fn random_arch(rng: &mut XorShift64, idx: usize) -> ArchSpec {
    let mut layers = Vec::new();
    let mut hw = 29usize;
    // 1-3 conv/pool stages that always fit.
    for _ in 0..(1 + rng.next_below(3)) {
        let k = 2 + rng.next_below(4); // 2..=5
        if k < hw {
            layers.push(LayerSpec::Conv { maps: 1 + rng.next_below(24), kernel: k });
            hw = hw - k + 1;
            // Pool with a window that divides hw, if any.
            for w in [2usize, 3, 5] {
                if hw % w == 0 && hw / w >= 2 && rng.next_below(2) == 0 {
                    layers.push(LayerSpec::Pool { window: w });
                    hw /= w;
                    break;
                }
            }
        }
    }
    if rng.next_below(2) == 0 {
        layers.push(LayerSpec::Dense { units: 10 + rng.next_below(200) });
    }
    layers.push(LayerSpec::Dense { units: 10 });
    ArchSpec { name: format!("gen{idx}"), layers }
}

#[test]
fn prop_generated_archs_validate_and_count() {
    let mut rng = XorShift64::new(1010);
    for case in 0..CASES {
        let arch = random_arch(&mut rng, case);
        arch.validate().unwrap_or_else(|e| panic!("case {case}: {e} {arch:?}"));
        let counts = opcount::count(&arch).unwrap();
        assert!(counts.fprop.total() > 0);
        assert!(counts.bprop.total() > 0);
        // Backward costs at least as much as forward minus activation
        // bookkeeping — in our scheme it is always strictly more.
        assert!(counts.bprop.total() + counts.fprop.total() > counts.fprop.total());
        // JSON roundtrip of the generated arch.
        let back = ArchSpec::from_json(&arch.to_json()).unwrap();
        assert_eq!(back, arch, "case {case}");
    }
}

#[test]
fn prop_adding_a_dense_layer_increases_ops() {
    let mut rng = XorShift64::new(1111);
    for case in 0..60 {
        let arch = random_arch(&mut rng, case);
        let mut bigger = arch.clone();
        let insert_at = bigger.layers.len() - 1;
        bigger.layers.insert(insert_at, LayerSpec::Dense { units: 64 });
        let a = opcount::count(&arch).unwrap();
        let b = opcount::count(&bigger).unwrap();
        assert!(
            b.fprop.total() > a.fprop.total(),
            "case {case}: {arch:?}"
        );
    }
}

// ---------------------------------------------------------------------------
// Simulator vs random machine configs (no panics, sane outputs)
// ---------------------------------------------------------------------------

#[test]
fn prop_simulator_robust_across_machine_configs() {
    let mut rng = XorShift64::new(1212);
    let arch = ArchSpec::small();
    for case in 0..40 {
        let mut cfg = SimConfig::default();
        cfg.machine.cores = 1 + rng.next_below(128);
        cfg.machine.threads_per_core = 1 + rng.next_below(8);
        cfg.machine.clock_hz = 0.5e9 + rng.next_below(3) as f64 * 1e9;
        cfg.machine.cpi_ladder =
            (0..cfg.machine.threads_per_core).map(|i| 1.0 + i as f64 * 0.4).collect();
        let run = RunConfig {
            train_images: 1 + rng.next_below(2_000),
            test_images: rng.next_below(500),
            epochs: 1 + rng.next_below(4),
            threads: 1 + rng.next_below(cfg.machine.cores * cfg.machine.threads_per_core * 2),
        };
        let r = simulate_training(&arch, &run, &cfg)
            .unwrap_or_else(|e| panic!("case {case}: {e} cfg={cfg:?} run={run:?}"));
        assert!(r.total_s.is_finite() && r.total_s > 0.0, "case {case}");
        assert!(r.execution_s <= r.total_s);
    }
}

// ---------------------------------------------------------------------------
// Sim-axis memoization soundness (the ablation-sweep cache contract)
// ---------------------------------------------------------------------------

/// A random sim-axis variant: every override drawn independently, `None`
/// with positive probability so partial override sets are exercised.
fn random_sim_variant(rng: &mut XorShift64, name: String) -> micdl::sweep::SimVariant {
    let mut v = micdl::sweep::SimVariant { name, ..Default::default() };
    if rng.next_below(2) == 0 {
        v.clock_ghz = Some(0.5 + rng.next_below(30) as f64 * 0.1);
    }
    if rng.next_below(3) == 0 {
        v.cores = Some(2 + rng.next_below(96));
    }
    if rng.next_below(3) == 0 {
        v.threads_per_core = Some(1 + rng.next_below(6));
    }
    if rng.next_below(2) == 0 {
        v.fwd_cycles_per_op = Some(5.0 + rng.next_below(60) as f64);
    }
    if rng.next_below(3) == 0 {
        v.bwd_cycles_per_op = Some(5.0 + rng.next_below(30) as f64);
    }
    if rng.next_below(3) == 0 {
        v.exec_fraction = Some(0.3 + rng.next_below(7) as f64 * 0.1);
    }
    if rng.next_below(3) == 0 {
        v.l2_alpha = Some(rng.next_below(100) as f64 * 0.01);
    }
    if rng.next_below(4) == 0 {
        v.ring_beta = Some(rng.next_below(60) as f64 * 0.01);
    }
    if rng.next_below(4) == 0 {
        v.oversub_overhead = Some(rng.next_below(20) as f64 * 0.01);
    }
    if rng.next_below(4) == 0 {
        v.l2_ratio_cap = Some(0.5 + rng.next_below(6) as f64);
    }
    if rng.next_below(2) == 0 {
        v.seed = Some(rng.next_below(1 << 30) as u64);
    }
    v
}

#[test]
fn prop_distinct_resolved_sims_never_share_fingerprints() {
    // Differing fingerprints never collide: any variant that changes at
    // least one resolved field must key differently from the base and
    // from other differing variants; value-identical variants must key
    // identically (that is what lets same-config cells share entries).
    let mut rng = XorShift64::new(777);
    let base = SimConfig::default();
    let base_fp = base.fingerprint();
    for case in 0..CASES {
        let mut v = random_sim_variant(&mut rng, format!("v{case}"));
        // Fidelity is drawn here rather than in random_sim_variant: the
        // memoization properties run real measurements, where per-image
        // DES over paper-scale workloads would be prohibitively slow —
        // the fingerprint property only hashes.
        if rng.next_below(3) == 0 {
            v.fidelity = Some(if rng.next_below(2) == 0 {
                Fidelity::PerImage
            } else {
                Fidelity::Chunked
            });
        }
        let resolved = v.apply(&base);
        let changed = resolved.machine != base.machine
            || resolved.fwd_cycles_per_op != base.fwd_cycles_per_op
            || resolved.bwd_cycles_per_op != base.bwd_cycles_per_op
            || resolved.exec_fraction != base.exec_fraction
            || resolved.l2_alpha != base.l2_alpha
            || resolved.l2_ratio_cap != base.l2_ratio_cap
            || resolved.ring_beta != base.ring_beta
            || resolved.oversub_overhead != base.oversub_overhead
            || resolved.fidelity != base.fidelity
            || resolved.seed != base.seed;
        if changed {
            assert_ne!(resolved.fingerprint(), base_fp, "case {case}: {v:?}");
        } else {
            assert_eq!(resolved.fingerprint(), base_fp, "case {case}: {v:?}");
        }
        // Renaming a variant never changes its resolved fingerprint.
        let mut renamed = v.clone();
        renamed.name = format!("renamed{case}");
        assert_eq!(
            renamed.apply(&base).fingerprint(),
            resolved.fingerprint(),
            "case {case}"
        );
        // Applying the same variant twice is idempotent on the key.
        assert_eq!(
            v.apply(&resolved).fingerprint(),
            resolved.fingerprint(),
            "case {case}"
        );
    }
}

#[test]
fn prop_sim_axis_memoization_is_sound() {
    use micdl::sweep::{GridSpec, Strategy, SweepCache};
    // For random ablation grids: cells whose resolved fingerprints match
    // share cache entries (observable as hits + bit-identical values),
    // and a full second pass over the grid is 100% hits returning
    // bit-identical values.
    let mut rng = XorShift64::new(888);
    for case in 0..12 {
        let v = random_sim_variant(&mut rng, "x".into());
        let mut twin = v.clone();
        twin.name = "y".into(); // same values, different name
        let distinct = {
            let mut d = random_sim_variant(&mut rng, "z".into());
            // Force at least one resolved difference from v.
            d.seed = Some(v.seed.unwrap_or(SimConfig::default().seed) ^ 0xBEEF);
            d
        };
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![1 + rng.next_below(240), 241 + rng.next_below(200)],
            strategies: vec![Strategy::A],
            sims: vec![v, twin, distinct],
            measure: true,
            ..GridSpec::default()
        };
        let cache = SweepCache::new();
        let scenarios = grid.enumerate();
        assert_eq!(scenarios.len(), 6);
        let first: Vec<f64> = scenarios
            .iter()
            .map(|s| cache.measured_s(&grid, s).unwrap())
            .collect();
        // Variant "y" (ids 2,3) re-hit "x"'s entries (ids 0,1)
        // bit-for-bit; the distinct variant never shares with either.
        assert_eq!(first[0].to_bits(), first[2].to_bits(), "case {case}");
        assert_eq!(first[1].to_bits(), first[3].to_bits(), "case {case}");
        let after_first = cache.stats();
        // Exactly two variants computed: 2 workloads × 2 + 2 cost builds.
        assert_eq!(after_first.misses, 6, "case {case}: {after_first:?}");
        // Second pass: pure hits, bit-identical.
        let second: Vec<f64> = scenarios
            .iter()
            .map(|s| cache.measured_s(&grid, s).unwrap())
            .collect();
        for (i, (a, b)) in first.iter().zip(second.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case} cell {i}");
        }
        let after_second = cache.stats();
        assert_eq!(after_second.misses, after_first.misses, "case {case}");
        assert_eq!(
            after_second.hits,
            after_first.hits + 6,
            "case {case}"
        );
    }
}

// ---------------------------------------------------------------------------
// Lab store: disk round-trip fidelity and key isolation
// ---------------------------------------------------------------------------

#[test]
fn prop_store_roundtrips_any_json_payload_exactly() {
    // Whatever JSON payload goes into the disk store comes back equal
    // (the emit/parse round-trip the persistence layer rests on), and
    // the hit/miss counters track exactly one miss then one hit per key.
    use micdl::lab::{Kind, Store};
    let dir = micdl::util::tmp::TempDir::new("prop-store").unwrap();
    let store = Store::open(dir.path()).unwrap();
    let mut rng = XorShift64::new(1111);
    for case in 0..CASES {
        let payload = random_json(&mut rng, 3);
        let key = format!("cell:v1:prop:{case}");
        assert!(store.get(Kind::Cells, &key).is_none(), "case {case}");
        store.put(Kind::Cells, &key, payload.clone()).unwrap();
        let back = store.get(Kind::Cells, &key).unwrap();
        assert_eq!(back, payload, "case {case}");
    }
    let stats = store.stats();
    assert_eq!(stats.misses, CASES as u64);
    assert_eq!(stats.hits, CASES as u64);
}

#[test]
fn prop_store_entries_never_leak_across_fingerprints() {
    // The no-leak property behind "warm runs are safe": every key embeds
    // its simulator fingerprint (and the cell keys their full axis
    // coordinates), so an entry persisted under one resolved simulator
    // configuration is never served for a different one, and the params
    // and cell namespaces never collide even for equal axis values.
    use micdl::lab::{cell_key, measured_key, params_key, Kind, Store};
    use micdl::sweep::Strategy;
    let dir = micdl::util::tmp::TempDir::new("prop-store-leak").unwrap();
    let store = Store::open(dir.path()).unwrap();
    let base = SimConfig::default();
    let mut rng = XorShift64::new(2222);
    for case in 0..CASES {
        let v = random_sim_variant(&mut rng, format!("v{case}"));
        let resolved = v.apply(&base);
        let (fp_a, fp_b) = (base.fingerprint(), resolved.fingerprint());
        if fp_a == fp_b {
            continue; // the variant resolved value-identical to the base
        }
        let threads = 1 + rng.next_below(3840);
        let source = if rng.next_below(2) == 0 {
            ParamSource::Paper
        } else {
            ParamSource::Simulator
        };
        let strategy = if rng.next_below(2) == 0 { Strategy::A } else { Strategy::B };
        let keys_a = [
            params_key("small", source, fp_a),
            cell_key("small", strategy.as_str(), threads, 60_000, 10_000, 70, source, fp_a),
            measured_key("small", threads, 60_000, 10_000, 70, fp_a),
        ];
        let keys_b = [
            params_key("small", source, fp_b),
            cell_key("small", strategy.as_str(), threads, 60_000, 10_000, 70, source, fp_b),
            measured_key("small", threads, 60_000, 10_000, 70, fp_b),
        ];
        for (kind, (a, b)) in [Kind::Params, Kind::Cells, Kind::Measured]
            .into_iter()
            .zip(keys_a.iter().zip(keys_b.iter()))
        {
            assert_ne!(a, b, "case {case}: fingerprint not in the {kind:?} key");
            store
                .put(kind, a, Json::obj(vec![("case", Json::num(case as f64))]))
                .unwrap();
            assert!(
                store.peek(kind, b).is_none(),
                "case {case}: {kind:?} entry for fp {fp_a:016x} served for {fp_b:016x}"
            );
            assert!(store.peek(kind, a).is_some(), "case {case}");
        }
        // Same coordinates, different source → different cell entry.
        let other = match source {
            ParamSource::Paper => ParamSource::Simulator,
            _ => ParamSource::Paper,
        };
        assert!(
            store
                .peek(
                    Kind::Cells,
                    &cell_key(
                        "small",
                        strategy.as_str(),
                        threads,
                        60_000,
                        10_000,
                        70,
                        other,
                        fp_a
                    )
                )
                .is_none(),
            "case {case}: cell leaked across param sources"
        );
    }
}

#[test]
fn prop_parallel_ablation_sweeps_bit_identical_to_serial() {
    use micdl::sweep::{GridSpec, Strategy, SweepRunner};
    let mut rng = XorShift64::new(999);
    for case in 0..6 {
        let sims = (0..2 + rng.next_below(3))
            .map(|i| random_sim_variant(&mut rng, format!("v{i}")))
            .collect::<Vec<_>>();
        let mut grid = GridSpec {
            archs: vec![ArchSpec::small(), ArchSpec::medium()],
            threads: vec![1 + rng.next_below(120), 121 + rng.next_below(240)],
            strategies: vec![Strategy::A, Strategy::B],
            sims,
            measure: true,
            ..GridSpec::default()
        };
        grid.normalize();
        let serial = SweepRunner::serial().run(&grid).unwrap();
        let parallel = SweepRunner::new(4).run(&grid).unwrap();
        assert_eq!(serial.len(), parallel.len(), "case {case}");
        for (s, p) in serial.results.iter().zip(parallel.results.iter()) {
            assert_eq!(s.scenario, p.scenario, "case {case}");
            assert_eq!(
                s.prediction.total_s.to_bits(),
                p.prediction.total_s.to_bits(),
                "case {case} id {}",
                s.scenario.id
            );
            assert_eq!(
                s.measured_s.unwrap().to_bits(),
                p.measured_s.unwrap().to_bits(),
                "case {case} id {}",
                s.scenario.id
            );
            assert_eq!(
                s.delta_pct.unwrap().to_bits(),
                p.delta_pct.unwrap().to_bits(),
                "case {case} id {}",
                s.scenario.id
            );
        }
    }
}

#[test]
fn prop_merged_shards_bit_identical_to_unsharded_serial_run() {
    use micdl::lab::Lab;
    use micdl::sweep::{merge_shards, GridSpec, Strategy, SweepResults, SweepRunner};
    use micdl::util::json::Json;
    use micdl::util::tmp::TempDir;

    // The stable payload: everything in the JSON dump that is a pure
    // function of the evaluated grid (wall/cache/store/workers are
    // per-run telemetry and legitimately differ across process shapes).
    fn stable_payload(results: &SweepResults) -> String {
        let doc = Json::parse(&results.to_json().emit()).unwrap();
        ["grid", "scenarios", "accuracy", "results"]
            .map(|key| doc.get(key).unwrap().emit())
            .join("\n")
    }

    let mut rng = XorShift64::new(4242);
    for case in 0..5 {
        let sims = (0..1 + rng.next_below(2))
            .map(|i| random_sim_variant(&mut rng, format!("v{i}")))
            .collect::<Vec<_>>();
        let mut grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![1 + rng.next_below(120), 121 + rng.next_below(240)],
            strategies: vec![Strategy::A, Strategy::B],
            sims,
            measure: true,
            ..GridSpec::default()
        };
        grid.normalize();
        let serial = SweepRunner::serial().run(&grid).unwrap();
        // Any shard count up to the cell count (the tentpole contract).
        let n = 1 + rng.next_below(grid.len());

        // Storeless shards merge bit-identically to the serial run:
        // per-result float bits, accuracy aggregation, JSON payload.
        let shards: Vec<SweepResults> = (0..n)
            .map(|k| SweepRunner::serial().run_shard(&grid, k, n).unwrap())
            .collect();
        let merged = merge_shards(&grid, shards).unwrap();
        assert_eq!(serial.len(), merged.len(), "case {case} n {n}");
        for (s, m) in serial.results.iter().zip(merged.results.iter()) {
            assert_eq!(s.scenario, m.scenario, "case {case} n {n}");
            assert_eq!(
                s.prediction.total_s.to_bits(),
                m.prediction.total_s.to_bits(),
                "case {case} n {n} id {}",
                s.scenario.id
            );
            assert_eq!(
                s.measured_s.unwrap().to_bits(),
                m.measured_s.unwrap().to_bits(),
                "case {case} n {n} id {}",
                s.scenario.id
            );
        }
        assert_eq!(
            stable_payload(&merged),
            stable_payload(&serial),
            "case {case} n {n}"
        );

        // Store accounting. Shards run sequentially against one shared
        // fresh store miss each unique key exactly once grid-wide —
        // the same total an unsharded run against its own fresh store
        // records — because whichever shard touches a key first
        // persists it for the rest.
        let shard_dir = TempDir::new("shard-prop").unwrap();
        let shard_lab = Lab::open(shard_dir.path()).unwrap();
        let mut shard_misses = 0;
        for k in 0..n {
            let before = shard_lab.store().stats();
            shard_lab.run_shard(&grid, k, n, 0).unwrap();
            shard_misses += shard_lab.store().stats().since(&before).misses;
        }
        let whole_dir = TempDir::new("shard-prop-whole").unwrap();
        let whole_lab = Lab::open(whole_dir.path()).unwrap();
        whole_lab.run(&grid, 0).unwrap();
        assert_eq!(
            shard_misses,
            whole_lab.store().stats().misses,
            "case {case} n {n}: sharding changed the store miss total"
        );
        // The driver's merge pass: a full run over the shard-warmed
        // store is pure hits and reproduces the serial payload.
        let before = shard_lab.store().stats();
        let warm = shard_lab.run(&grid, 0).unwrap();
        assert_eq!(
            shard_lab.store().stats().since(&before).misses,
            0,
            "case {case} n {n}: warm merge pass missed"
        );
        assert_eq!(
            stable_payload(&warm),
            stable_payload(&serial),
            "case {case} n {n}"
        );
    }
}

// ---------------------------------------------------------------------------
// Serve engine: batched prediction ≡ the sweep it abbreviates
// ---------------------------------------------------------------------------

#[test]
fn prop_predict_batch_bit_identical_to_sweep_cells() {
    use micdl::lab::Lab;
    use micdl::serve::{PredictEngine, Query, QueryBatch};
    use micdl::sweep::{Strategy, SweepResults, SweepRunner};
    use micdl::util::tmp::TempDir;
    use std::sync::Arc;

    fn sweep_rows(results: &SweepResults) -> Vec<String> {
        results
            .to_json()
            .get("results")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(Json::emit)
            .collect()
    }

    let archs = ["small", "medium", "large"];
    let mut rng = XorShift64::new(9090);
    for case in 0..6 {
        // A random batch: 1–4 queries, each with its own architecture,
        // strategy subset, thread ladder, workload, and (sometimes) a
        // random sim-axis variant.
        let queries: Vec<Query> = (0..1 + rng.next_below(4))
            .map(|qi| {
                let mut threads: Vec<usize> =
                    (0..1 + rng.next_below(4)).map(|_| 1 + rng.next_below(244)).collect();
                threads.sort();
                threads.dedup();
                Query {
                    arch: archs[rng.next_below(archs.len())].to_string(),
                    strategies: match rng.next_below(3) {
                        0 => vec![Strategy::A],
                        1 => vec![Strategy::B],
                        _ => vec![Strategy::A, Strategy::B],
                    },
                    threads,
                    train_images: 1_000 + rng.next_below(100_000),
                    test_images: rng.next_below(20_000),
                    epochs: if rng.next_below(2) == 0 {
                        Some(1 + rng.next_below(100))
                    } else {
                        None
                    },
                    sim: if rng.next_below(2) == 0 {
                        Some(random_sim_variant(&mut rng, format!("v{case}_{qi}")))
                    } else {
                        None
                    },
                }
            })
            .collect();
        let batch = QueryBatch { queries };

        // A parallel engine's per-query rows are byte-identical to a
        // serial reference sweep of that query's expanded grid.
        let engine = PredictEngine::new(ParamSource::Paper, 4);
        let results = engine.eval_batch(&batch).unwrap();
        for (q, res) in batch.queries.iter().zip(&results) {
            let grid = q.to_grid(ParamSource::Paper).unwrap();
            let reference = SweepRunner::serial().run(&grid).unwrap();
            let rows: Vec<String> = res.rows().iter().map(Json::emit).collect();
            assert_eq!(rows, sweep_rows(&reference), "case {case} arch {}", q.arch);
        }

        // Warm-store replay: a fresh engine over the store the first
        // pass populated serves the whole batch from disk — identical
        // bytes, zero calibration resolutions, zero store misses.
        let tmp = TempDir::new("predict-prop").unwrap();
        let lab = Lab::open(tmp.path()).unwrap();
        let cold = PredictEngine::new(ParamSource::Paper, 1).with_store(Arc::clone(lab.store()));
        let rows_cold: Vec<String> = cold
            .eval_batch(&batch)
            .unwrap()
            .iter()
            .flat_map(|q| q.rows())
            .map(|r| r.emit())
            .collect();
        let lab2 = Lab::open(tmp.path()).unwrap();
        let warm = PredictEngine::new(ParamSource::Paper, 1).with_store(Arc::clone(lab2.store()));
        let rows_warm: Vec<String> = warm
            .eval_batch(&batch)
            .unwrap()
            .iter()
            .flat_map(|q| q.rows())
            .map(|r| r.emit())
            .collect();
        assert_eq!(rows_warm, rows_cold, "case {case}");
        let stats = warm.stats();
        assert_eq!(stats.calibration_resolutions, 0, "case {case}: {stats:?}");
        assert_eq!(stats.store.unwrap().misses, 0, "case {case}: {stats:?}");
    }
}

// ---------------------------------------------------------------------------
// Strategy (c): residual-fit determinism & fingerprint isolation
// ---------------------------------------------------------------------------

#[test]
fn prop_residual_fit_deterministic_and_fingerprint_isolated() {
    use micdl::calibration::{residual, Calibration, ResidualSource};
    use micdl::perfmodel::StrategyB;

    let archs = ArchSpec::paper_archs();
    let mut rng = XorShift64::new(0xC0DE);
    for case in 0..16 {
        let arch = &archs[rng.next_below(archs.len())];
        let sim = SimConfig { seed: rng.next_u64(), ..SimConfig::default() };
        let cal = Calibration::new(ParamSource::Paper);
        let params = cal.resolve(arch, &sim).unwrap();
        let b = StrategyB::from_params(&params).unwrap();
        // Determinism: refitting from the same coordinates reproduces
        // the coefficients bit for bit, under the same training hash.
        let m1 = residual::ResidualModel::fit(arch, &b, &sim, ParamSource::Paper).unwrap();
        let m2 = residual::ResidualModel::fit(arch, &b, &sim, ParamSource::Paper).unwrap();
        assert_eq!(m1.weights.len(), residual::FEATURE_NAMES.len(), "case {case}");
        for (i, (w1, w2)) in m1.weights.iter().zip(m2.weights.iter()).enumerate() {
            assert_eq!(w1.to_bits(), w2.to_bits(), "case {case} weight {i}");
        }
        assert_eq!(m1.train_hash, m2.train_hash, "case {case}");
        assert_eq!(m1.seed, sim.seed, "case {case}");
        // A reseeded configuration is a different training grid (the
        // jittered workload moves), hence a different fingerprint.
        let other = SimConfig { seed: sim.seed ^ 0x5A5A, ..sim.clone() };
        assert_ne!(
            residual::training_runs(arch, sim.seed),
            residual::training_runs(arch, other.seed),
            "case {case}: jittered workload must move with the seed"
        );
        let m3 = residual::ResidualModel::fit(arch, &b, &other, ParamSource::Paper).unwrap();
        assert_ne!(m1.train_hash, m3.train_hash, "case {case}");
        // The memoizing source: one fit per (arch, fingerprint), never a
        // leak across fingerprints.
        let src = ResidualSource::new(ParamSource::Paper);
        let r1 = src.resolve(arch, &sim, &b).unwrap();
        let r1_again = src.resolve(arch, &sim, &b).unwrap();
        assert_eq!(src.fits(), 1, "case {case}: same coordinates memoize");
        assert_eq!(r1.train_hash, r1_again.train_hash, "case {case}");
        let r3 = src.resolve(arch, &other, &b).unwrap();
        assert_eq!(src.fits(), 2, "case {case}: reseeded sim refits");
        assert_ne!(r1.train_hash, r3.train_hash, "case {case}");
        assert_eq!(r1.train_hash, m1.train_hash, "case {case}");
        assert_eq!(r3.train_hash, m3.train_hash, "case {case}");
    }
}

#[test]
fn prop_residual_sweeps_bit_identical_serial_vs_parallel() {
    use micdl::sweep::{GridSpec, Strategy, SweepResults, SweepRunner};

    fn stable_payload(results: &SweepResults) -> String {
        let doc = Json::parse(&results.to_json().emit()).unwrap();
        ["grid", "scenarios", "accuracy", "results"]
            .map(|key| doc.get(key).unwrap().emit())
            .join("\n")
    }

    // Random measured [b, c] grids: the residual fit runs inside the
    // sweep engine, and its training must be bit-identical whatever the
    // worker count — coefficients, (c)-row payloads, everything.
    let all = ArchSpec::paper_archs();
    let mut rng = XorShift64::new(0xCAB1E);
    for case in 0..4 {
        let mut picked = vec![
            all[rng.next_below(all.len())].clone(),
            all[rng.next_below(all.len())].clone(),
        ];
        picked.dedup_by(|a, b| a.name == b.name);
        let mut grid = GridSpec {
            archs: picked,
            threads: vec![1 + rng.next_below(240), 241 + rng.next_below(3600)],
            strategies: vec![Strategy::B, Strategy::C],
            measure: true,
            ..GridSpec::default()
        };
        grid.normalize();
        let serial = SweepRunner::serial().run(&grid).unwrap();
        for workers in [2usize, 4] {
            let parallel = SweepRunner::new(workers).run(&grid).unwrap();
            assert_eq!(serial.len(), parallel.len(), "case {case} workers {workers}");
            for (s, p) in serial.results.iter().zip(parallel.results.iter()) {
                assert_eq!(s.scenario, p.scenario, "case {case} workers {workers}");
                assert_eq!(
                    s.prediction.total_s.to_bits(),
                    p.prediction.total_s.to_bits(),
                    "case {case} workers {workers} id {}",
                    s.scenario.id
                );
                assert_eq!(
                    s.measured_s.map(f64::to_bits),
                    p.measured_s.map(f64::to_bits),
                    "case {case} workers {workers} id {}",
                    s.scenario.id
                );
            }
            assert_eq!(
                stable_payload(&parallel),
                stable_payload(&serial),
                "case {case} workers {workers}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Single-flight cache: exactly one computation per distinct key, any W
// ---------------------------------------------------------------------------

#[test]
fn prop_single_flight_sweeps_compute_each_distinct_key_exactly_once() {
    use micdl::sweep::{GridSpec, Strategy, SweepCache, SweepResults, SweepRunner};

    fn stable_payload(results: &SweepResults) -> String {
        let doc = Json::parse(&results.to_json().emit()).unwrap();
        ["grid", "scenarios", "accuracy", "results"]
            .map(|key| doc.get(key).unwrap().emit())
            .join("\n")
    }

    // The duplicate-work contract, property-tested: for random grids
    // (strategy (c) included) and any worker count, the sweep performs
    // exactly one expensive computation per distinct key — model builds
    // per (arch, strategy, fingerprint), cost tables and calibration
    // resolutions and residual fits per (arch, fingerprint), workload
    // measurements per (arch, workload, fingerprint) — and the parallel
    // payload stays byte-identical to the serial reference.
    let all = ArchSpec::paper_archs();
    let mut rng = XorShift64::new(0x51F1);
    for case in 0..4 {
        let mut archs = vec![
            all[rng.next_below(all.len())].clone(),
            all[rng.next_below(all.len())].clone(),
        ];
        archs.dedup_by(|a, b| a.name == b.name);
        let strategies = match rng.next_below(4) {
            0 => vec![Strategy::A, Strategy::B],
            1 => vec![Strategy::B, Strategy::C],
            2 => vec![Strategy::A, Strategy::B, Strategy::C],
            _ => vec![Strategy::B],
        };
        let measure = rng.next_below(2) == 0;
        let mut grid = GridSpec {
            archs,
            threads: vec![1 + rng.next_below(240), 241 + rng.next_below(3600)],
            strategies,
            measure,
            ..GridSpec::default()
        };
        grid.normalize();

        // Distinct-key census for this grid (single machine, single
        // workload point, no sim axis → one fingerprint).
        let archs_n = grid.archs.len() as u64;
        let d_models = archs_n * grid.strategies.len() as u64;
        let d_costs = if measure { archs_n } else { 0 };
        let d_measured = if measure { archs_n * grid.threads.len() as u64 } else { 0 };
        let with_c = grid.strategies.contains(&Strategy::C);

        let serial = SweepRunner::serial().run(&grid).unwrap();
        for workers in [1usize, 2, 4, 8] {
            let res = SweepRunner::new(workers).run(&grid).unwrap();
            assert_eq!(
                res.cache.misses,
                d_models + d_costs + d_measured,
                "case {case} workers {workers}: {:?}",
                res.cache
            );
            assert_eq!(
                stable_payload(&res),
                stable_payload(&serial),
                "case {case} workers {workers}"
            );
        }

        // Resolution/fit counters under raw contention: 8 threads race
        // the same probe pattern the runner issues over one shared
        // cache — still one calibration resolution per (arch,
        // fingerprint), one residual fit per (arch, fingerprint) when
        // (c) is on the grid, and the per-memo miss census is exact.
        let cache = SweepCache::new();
        let scenarios = grid.enumerate();
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for scn in &scenarios {
                        cache.model(&grid, scn).unwrap();
                        if grid.measure {
                            cache.measured_s(&grid, scn).unwrap();
                        }
                    }
                });
            }
        });
        assert_eq!(cache.calibration_resolutions(), archs_n, "case {case}");
        assert_eq!(
            cache.residual_fits(),
            if with_c { archs_n } else { 0 },
            "case {case}"
        );
        let stats = cache.stats();
        assert_eq!(
            stats.misses,
            d_models + d_costs + d_measured,
            "case {case}: {stats:?}"
        );
    }
}
