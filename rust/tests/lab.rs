//! Lab integration tests: the persistence acceptance criteria.
//!
//! * A warm identical run performs zero model / cost-model / measurement
//!   recomputation — every cell is a store hit — and its results are
//!   bit-identical to the cold run.
//! * An interrupted sweep (modelled as a sub-grid run, then the full
//!   grid with the same lab) resumes without recomputing the persisted
//!   cells and matches a cold full run bit for bit.
//! * A store-backed run is bit-identical to a storeless run, and the
//!   store never serves a measurement-less cell to a measuring grid.

use micdl::config::ArchSpec;
use micdl::lab::Lab;
use micdl::sweep::{GridSpec, ScenarioResult, Strategy, StoreStats, SweepCache, SweepRunner};
use micdl::util::tmp::TempDir;

fn measured_grid(threads: Vec<usize>) -> GridSpec {
    GridSpec {
        archs: vec![ArchSpec::small()],
        threads,
        strategies: vec![Strategy::A, Strategy::B],
        measure: true,
        ..GridSpec::default()
    }
}

/// Every result field of `a` equals `b` bit for bit.
fn assert_bit_identical(a: &[ScenarioResult], b: &[ScenarioResult], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.scenario, y.scenario, "{tag}");
        for (l, r) in [
            (x.prediction.prep_s, y.prediction.prep_s),
            (x.prediction.train_s, y.prediction.train_s),
            (x.prediction.test_s, y.prediction.test_s),
            (x.prediction.mem_s, y.prediction.mem_s),
            (x.prediction.total_s, y.prediction.total_s),
        ] {
            assert_eq!(l.to_bits(), r.to_bits(), "{tag} id {}", x.scenario.id);
        }
        assert_eq!(
            x.measured_s.map(f64::to_bits),
            y.measured_s.map(f64::to_bits),
            "{tag} id {}",
            x.scenario.id
        );
        assert_eq!(
            x.delta_pct.map(f64::to_bits),
            y.delta_pct.map(f64::to_bits),
            "{tag} id {}",
            x.scenario.id
        );
    }
}

#[test]
fn warm_rerun_is_pure_store_hits_and_bit_identical() {
    let dir = TempDir::new("lab-warm").unwrap();
    let grid = measured_grid(vec![1, 15]);
    let cold = Lab::open(dir.path()).unwrap().run(&grid, 1).unwrap();
    // Cold: every store lookup misses — 4 cells + 1 shared param set +
    // 2 strategy-independent measurements.
    assert_eq!(cold.store, Some(StoreStats { hits: 0, misses: 7 }), "{:?}", cold.store);
    // Warm, through a fresh facade (cold in-process caches): every cell
    // serves from disk before any model is even built.
    let warm = Lab::open(dir.path()).unwrap().run(&grid, 1).unwrap();
    let stats = warm.store.expect("store attached");
    assert_eq!(stats, StoreStats { hits: 4, misses: 0 }, "{stats:?}");
    assert_eq!(stats.hit_rate(), 1.0);
    // Nothing recomputed means nothing entered the in-process cache.
    assert_eq!(warm.cache.misses, 0, "{:?}", warm.cache);
    assert_bit_identical(&cold.results, &warm.results, "cold vs warm");
    // The payload a script consumes (grid + per-cell rows + accuracy) is
    // byte-identical run over run.
    let strip = |r: &micdl::sweep::SweepResults| {
        let doc = r.to_json();
        (
            doc.get("grid").unwrap().emit(),
            doc.get("results").unwrap().emit(),
            doc.get("accuracy").unwrap().emit(),
        )
    };
    assert_eq!(strip(&cold), strip(&warm));
}

#[test]
fn interrupted_sweep_resumes_without_recomputing_persisted_cells() {
    // An interruption mid-grid leaves a prefix of cells persisted; the
    // resumed run must serve exactly those from the store and compute
    // only the rest, landing bit-identical to a cold full run.
    let shared = TempDir::new("lab-resume").unwrap();
    let partial = Lab::open(shared.path()).unwrap();
    let sub = measured_grid(vec![1]);
    let first = partial.run(&sub, 1).unwrap();
    assert_eq!(first.store.unwrap().hits, 0);
    // "Resume": the full grid against the same lab.
    let full = measured_grid(vec![1, 15]);
    let resumed = Lab::open(shared.path()).unwrap().run(&full, 1).unwrap();
    let stats = resumed.store.unwrap();
    // The 2 persisted cells hit (plus the persisted param set); only the
    // threads=15 half of the grid computes.
    assert!(stats.hits >= 2, "{stats:?}");
    assert_eq!(stats.misses, 3, "{stats:?}");
    // Bit-identical to a cold full run in a fresh lab.
    let fresh = TempDir::new("lab-cold").unwrap();
    let cold = Lab::open(fresh.path()).unwrap().run(&full, 1).unwrap();
    assert_bit_identical(&cold.results, &resumed.results, "cold vs resumed");
    // The lab kept one manifest per distinct grid, both complete.
    let lab = Lab::open(shared.path()).unwrap();
    let runs = lab.list_runs().unwrap();
    assert_eq!(runs.len(), 2);
    for m in &runs {
        assert_eq!(m.get("status").unwrap().as_str(), Some("complete"));
    }
    assert!(lab.find_run(&full).unwrap().is_some());
}

#[test]
fn store_backed_runs_match_storeless_bitwise() {
    let dir = TempDir::new("lab-parity").unwrap();
    let grid = measured_grid(vec![61]);
    let storeless = SweepRunner::serial().run(&grid).unwrap();
    assert!(storeless.store.is_none());
    let stored = Lab::open(dir.path()).unwrap().run(&grid, 1).unwrap();
    assert!(stored.store.is_some());
    assert_bit_identical(&storeless.results, &stored.results, "storeless vs stored");
    // And the storeless footer/JSON carry no store section at all.
    assert!(storeless.to_json().get("store").is_none());
    assert!(!storeless.render(false).contains("store:"));
    assert!(stored.to_json().get("store").is_some());
    assert!(stored.render(false).contains("store:"));
}

#[test]
fn measuring_grid_rejects_prediction_only_cells_then_upgrades_them() {
    // A cell persisted by a prediction-only sweep must not satisfy a
    // measuring sweep (it has no measurement); the measuring run
    // recomputes and overwrites it, after which both grid flavours hit.
    let dir = TempDir::new("lab-upgrade").unwrap();
    let mut grid = measured_grid(vec![15]);
    grid.strategies = vec![Strategy::A];
    grid.measure = false;
    let lab = Lab::open(dir.path()).unwrap();
    let predicted = lab.run(&grid, 1).unwrap();
    assert_eq!(predicted.store, Some(StoreStats { hits: 0, misses: 2 }));
    grid.measure = true;
    let measuring = Lab::open(dir.path()).unwrap().run(&grid, 1).unwrap();
    let stats = measuring.store.unwrap();
    // The stale cell reads as a miss; only the param set hits.
    assert_eq!(stats, StoreStats { hits: 1, misses: 2 }, "{stats:?}");
    assert!(measuring.results[0].measured_s.is_some());
    // Upgraded cell now serves both grid flavours from disk.
    let warm_measure = Lab::open(dir.path()).unwrap().run(&grid, 1).unwrap();
    assert_eq!(warm_measure.store, Some(StoreStats { hits: 1, misses: 0 }));
    grid.measure = false;
    let warm_predict = Lab::open(dir.path()).unwrap().run(&grid, 1).unwrap();
    assert_eq!(warm_predict.store, Some(StoreStats { hits: 1, misses: 0 }));
    assert!(warm_predict.results[0].measured_s.is_none());
    assert_bit_identical(&predicted.results, &warm_predict.results, "predict flavours");
}

/// A strategy-(b)+(c) measuring grid over the small CNN: the residual
/// round-trip fixture.
fn residual_grid() -> GridSpec {
    GridSpec {
        archs: vec![ArchSpec::small()],
        threads: vec![1, 15],
        strategies: vec![Strategy::B, Strategy::C],
        measure: true,
        ..GridSpec::default()
    }
}

#[test]
fn warm_residual_rerun_is_pure_store_hits_with_zero_refits() {
    use micdl::simulator::SimConfig;
    use std::sync::Arc;
    let dir = TempDir::new("lab-residual").unwrap();
    let grid = residual_grid();
    let cold = Lab::open(dir.path()).unwrap().run(&grid, 1).unwrap();
    // Cold: 4 cells + 1 shared param set + 1 fitted residual model + 2
    // strategy-independent measurements, all misses.
    assert_eq!(cold.store, Some(StoreStats { hits: 0, misses: 8 }), "{:?}", cold.store);
    // Warm: every cell serves from disk before any model (and therefore
    // any residual fit) is even constructed.
    let warm = Lab::open(dir.path()).unwrap().run(&grid, 1).unwrap();
    assert_eq!(warm.store, Some(StoreStats { hits: 4, misses: 0 }), "{:?}", warm.store);
    assert_eq!(warm.cache.misses, 0, "{:?}", warm.cache);
    assert_bit_identical(&cold.results, &warm.results, "cold vs warm residual");
    // Forcing model construction against the warm store loads the
    // persisted coefficients instead of refitting: zero fits.
    let lab = Lab::open(dir.path()).unwrap();
    let cache = SweepCache::new().with_store(Arc::clone(lab.store()));
    for scn in grid.enumerate() {
        cache.model(&grid, &scn).unwrap();
    }
    assert_eq!(cache.residual_fits(), 0, "warm store must serve the fit");
    // The storeless control: the same models without a store fit exactly
    // once (one arch × one sim fingerprint).
    let storeless = SweepCache::new();
    for scn in grid.enumerate() {
        storeless.model(&grid, &scn).unwrap();
    }
    assert_eq!(storeless.residual_fits(), 1, "storeless control refits once");
    // The persisted payload round-trips the exact training seed.
    let sim = SimConfig::default();
    let doc = lab
        .trace_params("small", micdl::perfmodel::ParamSource::Paper, &sim)
        .expect("params persisted");
    let residual = doc.get("residual").expect("residual provenance persisted");
    let entry = residual.get("entry").unwrap();
    assert_eq!(
        entry.get("seed").unwrap().as_str(),
        Some(format!("{:016x}", sim.seed).as_str())
    );
}

#[test]
fn trace_params_carries_residual_provenance() {
    use micdl::calibration::residual;
    use micdl::perfmodel::ParamSource;
    use micdl::simulator::SimConfig;
    let dir = TempDir::new("lab-residual-trace").unwrap();
    let lab = Lab::open(dir.path()).unwrap();
    lab.run(&residual_grid(), 1).unwrap();
    let doc = lab
        .trace_params("small", ParamSource::Paper, &SimConfig::default())
        .expect("params persisted");
    // The base calibration entry is untouched…
    assert!(doc.get("key").unwrap().as_str().unwrap().starts_with("params:v1:small:paper:"));
    // …and the residual provenance rides along: canonical key, training-
    // grid hash, fit size and the full feature list.
    let res = doc.get("residual").expect("residual section");
    let key = res.get("key").unwrap().as_str().unwrap();
    assert!(key.starts_with("residual:v1:small:paper:"), "{key}");
    let entry = res.get("entry").unwrap();
    let train_hash = entry.get("train_hash").unwrap().as_str().unwrap();
    assert_eq!(train_hash.len(), 16, "{train_hash}");
    assert!(train_hash.chars().all(|c| c.is_ascii_hexdigit()), "{train_hash}");
    assert_eq!(entry.get("train_points").unwrap().as_usize(), Some(44));
    let features = entry.get("features").unwrap().as_arr().unwrap();
    assert_eq!(features.len(), residual::FEATURE_NAMES.len());
    for (got, want) in features.iter().zip(residual::FEATURE_NAMES.iter()) {
        assert_eq!(got.as_str(), Some(*want));
    }
    let weights = entry.get("weights").unwrap().as_arr().unwrap();
    assert_eq!(weights.len(), residual::FEATURE_NAMES.len());
    // A sim variant that never ran strategy (c) has no residual section.
    let other = SimConfig { seed: 7, ..SimConfig::default() };
    assert!(lab
        .trace_params("small", ParamSource::Paper, &other)
        .is_none());
}
