//! Sweep-engine integration tests: cross-product enumeration, cache
//! behaviour, parallel/serial equivalence, and the ≥1000-scenario grid
//! the CLI acceptance path exercises.

use micdl::config::ArchSpec;
use micdl::sweep::{parse_axis, GridSpec, Strategy, SweepRunner};
use micdl::util::json::Json;

fn mid_grid() -> GridSpec {
    GridSpec {
        archs: vec![ArchSpec::small(), ArchSpec::medium()],
        threads: vec![1, 15, 61, 240],
        strategies: vec![Strategy::A, Strategy::B],
        ..GridSpec::default()
    }
}

// ---------------------------------------------------------------------------
// Grid enumeration
// ---------------------------------------------------------------------------

#[test]
fn cross_product_count_matches_axes() {
    let grid = mid_grid();
    assert_eq!(grid.len(), 2 * 4 * 2);
    assert_eq!(grid.enumerate().len(), grid.len());
}

#[test]
fn enumeration_is_deterministic_and_ordered() {
    let grid = mid_grid();
    let a = grid.enumerate();
    let b = grid.enumerate();
    assert_eq!(a, b);
    for (i, s) in a.iter().enumerate() {
        assert_eq!(s.id, i, "ids must be the enumeration order");
    }
    // Lexicographic axis order: strategy is the innermost axis.
    assert_eq!(a[0].strategy, Strategy::A);
    assert_eq!(a[1].strategy, Strategy::B);
    assert_eq!(a[0].threads, a[1].threads);
    // Arch is the outermost axis.
    assert!(a.iter().take(8).all(|s| s.arch == 0));
    assert!(a.iter().skip(8).all(|s| s.arch == 1));
}

#[test]
fn normalize_dedups_every_axis() {
    let mut grid = GridSpec {
        archs: vec![ArchSpec::small(), ArchSpec::small(), ArchSpec::large()],
        threads: vec![240, 1, 240, 1, 61],
        images: vec![(100, 10), (100, 10)],
        epochs: vec![2, 2, 4],
        strategies: vec![Strategy::B, Strategy::B, Strategy::A],
        ..GridSpec::default()
    };
    grid.normalize();
    assert_eq!(grid.archs.len(), 2);
    assert_eq!(grid.threads, vec![240, 1, 61]);
    assert_eq!(grid.images, vec![(100, 10)]);
    assert_eq!(grid.epochs, vec![2, 4]);
    assert_eq!(grid.strategies, vec![Strategy::B, Strategy::A]);
    assert!(grid.validate().is_ok());
}

#[test]
fn axis_parser_handles_ranges_and_lists() {
    assert_eq!(parse_axis("1..244").unwrap().len(), 244);
    assert_eq!(parse_axis("1..244..4").unwrap().len(), 61);
    assert_eq!(parse_axis("1,15,30,60").unwrap(), vec![1, 15, 30, 60]);
}

// ---------------------------------------------------------------------------
// Cache behaviour
// ---------------------------------------------------------------------------

#[test]
fn cache_builds_each_model_once_per_key() {
    // 2 archs × 4 threads × 2 strategies = 16 scenarios, but only
    // 2 × 2 = 4 distinct (arch, strategy, machine) model keys.
    let res = SweepRunner::serial().run(&mid_grid()).unwrap();
    assert_eq!(res.cache.misses, 4);
    assert_eq!(res.cache.hits, 16 - 4);
    assert!(res.cache.hit_rate() > 0.7);
}

#[test]
fn measured_grid_shares_workload_measurements_across_strategies() {
    let grid = GridSpec { measure: true, ..mid_grid() };
    let res = SweepRunner::serial().run(&grid).unwrap();
    // Model keys: 4 misses. Cost models: one per (arch, machine) = 2.
    // Measurements: one per (arch, machine, workload) = 2 archs × 4
    // threads = 8 misses, hit by the second strategy of each point.
    assert_eq!(res.cache.misses, 4 + 2 + 8);
    // Every (a, b) pair shares the measured value bit-for-bit.
    for pair in res.results.chunks(2) {
        assert_eq!(
            pair[0].measured_s.unwrap().to_bits(),
            pair[1].measured_s.unwrap().to_bits()
        );
    }
}

#[test]
fn any_worker_count_performs_exactly_d_expensive_computations() {
    // The duplicate-work contract of the single-flight cache: a grid
    // with D distinct expensive keys performs exactly D computations
    // for ANY worker count — concurrent misses on one key coalesce.
    // On this measured grid D = 4 model keys + 2 cost tables + 8
    // workload measurements = 14, and every scenario makes exactly one
    // model probe and one measurement probe, plus one cost probe per
    // measurement computed (16 + 16 + 8 = 40 lookups).
    let grid = GridSpec { measure: true, ..mid_grid() };
    for workers in [1, 2, 4, 8, 16] {
        let res = SweepRunner::new(workers).run(&grid).unwrap();
        assert_eq!(res.cache.misses, 14, "workers = {workers}: {:?}", res.cache);
        assert_eq!(res.cache.lookups(), 40, "workers = {workers}: {:?}", res.cache);
        if workers == 1 {
            assert_eq!(res.cache.coalesced, 0, "serial runs never wait");
        }
    }
}

// ---------------------------------------------------------------------------
// Parallel vs serial equivalence
// ---------------------------------------------------------------------------

#[test]
fn parallel_results_bit_identical_to_serial() {
    let grid = GridSpec { measure: true, ..mid_grid() };
    let serial = SweepRunner::serial().run(&grid).unwrap();
    for workers in [2, 4, 16] {
        let parallel = SweepRunner::new(workers).run(&grid).unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.results.iter().zip(parallel.results.iter()) {
            assert_eq!(s.scenario, p.scenario);
            let sp = s.prediction;
            let pp = p.prediction;
            assert_eq!(sp.prep_s.to_bits(), pp.prep_s.to_bits());
            assert_eq!(sp.train_s.to_bits(), pp.train_s.to_bits());
            assert_eq!(sp.test_s.to_bits(), pp.test_s.to_bits());
            assert_eq!(sp.mem_s.to_bits(), pp.mem_s.to_bits());
            assert_eq!(sp.total_s.to_bits(), pp.total_s.to_bits());
            assert_eq!(
                s.measured_s.unwrap().to_bits(),
                p.measured_s.unwrap().to_bits()
            );
            assert_eq!(
                s.delta_pct.unwrap().to_bits(),
                p.delta_pct.unwrap().to_bits()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Scale: the ≥1000-scenario acceptance grid
// ---------------------------------------------------------------------------

#[test]
fn thousand_scenario_grid_evaluates_in_one_run() {
    let grid = GridSpec {
        threads: parse_axis("1..180").unwrap(),
        ..GridSpec::default()
    };
    // 3 archs × 180 thread counts × 2 strategies.
    assert_eq!(grid.len(), 1080);
    let res = SweepRunner::new(0).run(&grid).unwrap();
    assert_eq!(res.len(), 1080);
    for r in &res.results {
        assert!(
            r.prediction.total_s.is_finite() && r.prediction.total_s > 0.0,
            "scenario {:?}",
            r.scenario
        );
    }
    // The cache keeps model construction sublinear in grid size: 3 archs
    // × 2 strategies = 6 distinct keys over 1080 lookups. The memos are
    // single-flight, so even under a full parallel pool concurrent
    // first-misses on one key coalesce onto one computation — the miss
    // count is exact, not bounded.
    assert_eq!(res.cache.misses, 6, "{:?}", res.cache);
    assert_eq!(res.cache.hits, 1080 - 6, "{:?}", res.cache);
    assert!(res.cache.hit_rate() > 0.9);
}

// ---------------------------------------------------------------------------
// Output surfaces
// ---------------------------------------------------------------------------

#[test]
fn json_output_parses_and_indexes() {
    let res = SweepRunner::serial().run(&mid_grid()).unwrap();
    let doc = Json::parse(&res.to_json().emit()).unwrap();
    assert_eq!(doc.get("scenarios").unwrap().as_usize(), Some(16));
    let rows = doc.get("results").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 16);
    assert_eq!(rows[0].get("strategy").unwrap().as_str(), Some("a"));
    assert_eq!(rows[15].get("arch").unwrap().as_str(), Some("medium"));
}

#[test]
fn stride_lookup_agrees_with_value_lookup() {
    let res = SweepRunner::serial().run(&mid_grid()).unwrap();
    let by_stride = res.at(1, 0, 0, 0, 3, 1); // medium, p=240, strategy b
    let by_value = res.find("medium", 240, Strategy::B).unwrap();
    assert_eq!(by_stride.scenario.id, by_value.scenario.id);
    assert_eq!(by_stride.scenario.threads, 240);
    assert_eq!(by_stride.scenario.strategy, Strategy::B);
}
