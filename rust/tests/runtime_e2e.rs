//! End-to-end PJRT tests: load the AOT HLO artifacts, compile them on the
//! CPU PJRT client, and train — the full L1/L2/L3 composition.
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (with a notice) when `artifacts/meta.json` is absent so `cargo test`
//! stays green on a fresh checkout.

use std::path::PathBuf;

use micdl::dataset;
use micdl::runtime::{ArtifactRegistry, PjrtRuntime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn batch_of(
    data: &dataset::Dataset,
    start: usize,
    batch: usize,
) -> (Vec<f32>, Vec<i32>) {
    let mut xs = Vec::with_capacity(batch * dataset::IMAGE_PIXELS);
    let mut ys = Vec::with_capacity(batch);
    for k in 0..batch {
        let (img, label) = data.sample((start + k) % data.len());
        xs.extend_from_slice(img);
        ys.push(label as i32);
    }
    (xs, ys)
}

#[test]
fn artifacts_load_and_compile() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load(&dir).unwrap();
    reg.check_files().unwrap();
    let mut rt = PjrtRuntime::cpu().unwrap();
    assert!(rt.platform_name().to_lowercase().contains("cpu")
            || rt.platform_name().to_lowercase().contains("host"),
            "platform: {}", rt.platform_name());
    let arch = reg.arch("small").unwrap().clone();
    rt.compile_hlo(&arch.train_hlo).unwrap();
    rt.compile_hlo(&arch.infer_hlo).unwrap();
}

#[test]
fn small_cnn_trains_loss_decreases() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load(&dir).unwrap();
    let arch = reg.arch("small").unwrap().clone();
    let mut rt = PjrtRuntime::cpu().unwrap();
    let mut handle = rt.train_handle(&arch, reg.batch, reg.input_hw, 42).unwrap();

    let (train, _) = dataset::load_or_synth(None, 512, 64, 7);
    let mut first_losses = Vec::new();
    let mut last_losses = Vec::new();
    let steps = 30usize;
    for step in 0..steps {
        let (xs, ys) = batch_of(&train, step * reg.batch, reg.batch);
        let loss = rt.train_step(&mut handle, &xs, &ys).unwrap();
        assert!(loss.is_finite(), "step {step}: loss {loss}");
        if step < 3 {
            first_losses.push(loss);
        }
        if step >= steps - 3 {
            last_losses.push(loss);
        }
    }
    let first: f32 = first_losses.iter().sum::<f32>() / first_losses.len() as f32;
    let last: f32 = last_losses.iter().sum::<f32>() / last_losses.len() as f32;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert_eq!(handle.steps, steps as u64);
}

#[test]
fn inference_predictions_valid_classes() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load(&dir).unwrap();
    let arch = reg.arch("small").unwrap().clone();
    let mut rt = PjrtRuntime::cpu().unwrap();
    let mut handle = rt.train_handle(&arch, reg.batch, reg.input_hw, 3).unwrap();

    let (train, _) = dataset::load_or_synth(None, reg.batch, 8, 9);
    let (xs, _) = batch_of(&train, 0, reg.batch);
    let classes = rt.infer(&mut handle, &xs).unwrap();
    assert_eq!(classes.len(), reg.batch);
    assert!(classes.iter().all(|&c| c < reg.num_classes));
}

#[test]
fn training_improves_accuracy_on_synth() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load(&dir).unwrap();
    let arch = reg.arch("small").unwrap().clone();
    let mut rt = PjrtRuntime::cpu().unwrap();
    let mut handle = rt.train_handle(&arch, reg.batch, reg.input_hw, 11).unwrap();

    let (train, test) = dataset::load_or_synth(None, 2048, 256, 13);
    let mut accuracy = |rt: &mut PjrtRuntime,
                        handle: &mut micdl::runtime::TrainHandle|
     -> f64 {
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut start = 0;
        while start + reg.batch <= test.len() {
            let (xs, ys) = batch_of(&test, start, reg.batch);
            let classes = rt.infer(handle, &xs).unwrap();
            correct += classes
                .iter()
                .zip(ys.iter())
                .filter(|(&c, &y)| c == y as usize)
                .count();
            total += reg.batch;
            start += reg.batch;
        }
        correct as f64 / total as f64
    };

    let before = accuracy(&mut rt, &mut handle);
    for step in 0..80 {
        let (xs, ys) = batch_of(&train, step * reg.batch, reg.batch);
        rt.train_step(&mut handle, &xs, &ys).unwrap();
    }
    let after = accuracy(&mut rt, &mut handle);
    assert!(
        after > before.max(0.3),
        "accuracy did not improve: {before:.3} -> {after:.3}"
    );
}

#[test]
fn rejects_wrong_batch_sizes() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load(&dir).unwrap();
    let arch = reg.arch("small").unwrap().clone();
    let mut rt = PjrtRuntime::cpu().unwrap();
    let mut handle = rt.train_handle(&arch, reg.batch, reg.input_hw, 1).unwrap();
    let bad_images = vec![0.0f32; 10];
    let labels = vec![0i32; reg.batch];
    assert!(rt.train_step(&mut handle, &bad_images, &labels).is_err());
}
