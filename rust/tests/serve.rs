//! Integration tests for the serve subsystem: engine-vs-sweep
//! bit-identity, warm-store behaviour, and the embedded HTTP server
//! end-to-end over a real TCP socket.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use micdl::lab::Lab;
use micdl::perfmodel::ParamSource;
use micdl::serve::{predict_doc, PredictEngine, QueryBatch, Server};
use micdl::sweep::{Strategy, SweepResults, SweepRunner};
use micdl::util::json::Json;
use micdl::util::tmp::TempDir;

/// The sweep dump's `results[]` rows, as emitted bytes.
fn sweep_rows(results: &SweepResults) -> Vec<String> {
    results
        .to_json()
        .get("results")
        .and_then(Json::as_arr)
        .expect("sweep dump has results[]")
        .iter()
        .map(Json::emit)
        .collect()
}

#[test]
fn predict_rows_are_bit_identical_to_the_sweep_dump() {
    let text = r#"[
        {"arch": "small", "threads": [1, 15, 61, 240]},
        {"arch": "medium", "strategy": "a",
         "threads_range": {"from": 30, "to": 240, "step": 30},
         "train_images": 30000, "test_images": 5000, "epochs": 10},
        {"arch": "large", "strategy": "b", "threads": [240],
         "sim": {"name": "fast", "clock_ghz": 1.5}}
    ]"#;
    let batch = QueryBatch::from_json(text).unwrap();
    let engine = PredictEngine::new(ParamSource::Paper, 0);
    let results = engine.eval_batch(&batch).unwrap();
    for (q, res) in batch.queries.iter().zip(&results) {
        let grid = q.to_grid(ParamSource::Paper).unwrap();
        let sweep = SweepRunner::serial().run(&grid).unwrap();
        let serve_rows: Vec<String> = res.rows().iter().map(Json::emit).collect();
        assert_eq!(serve_rows, sweep_rows(&sweep), "arch {}", q.arch);
    }
}

#[test]
fn warm_store_batch_serves_cells_with_zero_resolutions() {
    let tmp = TempDir::new("serve-warm").unwrap();
    let batch = QueryBatch::from_json(
        r#"[{"arch": "small", "threads": [1, 15, 61]},
            {"arch": "medium", "strategy": "b", "threads": [15, 240]}]"#,
    )
    .unwrap();

    // Pass 1: a store-backed engine computes and persists every cell
    // (and its calibration entries).
    let lab = Lab::open(tmp.path()).unwrap();
    let first = PredictEngine::new(ParamSource::Paper, 1).with_store(Arc::clone(lab.store()));
    let rows_cold: Vec<String> = first
        .eval_batch(&batch)
        .unwrap()
        .iter()
        .flat_map(|q| q.rows())
        .map(|r| r.emit())
        .collect();
    assert!(first.stats().calibration_resolutions > 0);

    // Pass 2: a fresh engine over the same store — every cell is a
    // store hit, zero calibration resolutions, identical bytes.
    let lab2 = Lab::open(tmp.path()).unwrap();
    let second = PredictEngine::new(ParamSource::Paper, 1).with_store(Arc::clone(lab2.store()));
    let rows_warm: Vec<String> = second
        .eval_batch(&batch)
        .unwrap()
        .iter()
        .flat_map(|q| q.rows())
        .map(|r| r.emit())
        .collect();
    assert_eq!(rows_warm, rows_cold);
    let stats = second.stats();
    assert_eq!(
        stats.calibration_resolutions, 0,
        "warm store must serve every parameter table: {stats:?}"
    );
    let store = stats.store.expect("store attached");
    assert_eq!(store.misses, 0, "warm store must not miss: {store:?}");
    assert!(store.hits > 0);
}

#[test]
fn batch_strategy_grammar_matches_the_sweep_surfaces() {
    // The serve schema routes through Strategy::parse_list, so it
    // accepts and rejects exactly what CLI flags and sweep specs do —
    // same tokens, same error message.
    let err = QueryBatch::from_json(
        r#"[{"arch": "small", "strategy": "z", "threads": [1]}]"#,
    )
    .unwrap_err();
    assert!(
        err.to_string().contains("strategy must be a|b|c|both, got \"z\""),
        "{err}"
    );
    let batch = QueryBatch::from_json(
        r#"[{"arch": "small", "strategy": "all", "threads": [1]}]"#,
    )
    .unwrap();
    assert_eq!(
        batch.queries[0].strategies,
        vec![Strategy::A, Strategy::B, Strategy::C]
    );
    let batch = QueryBatch::from_json(
        r#"[{"arch": "small", "strategy": "b,c", "threads": [1]}]"#,
    )
    .unwrap();
    assert_eq!(batch.queries[0].strategies, vec![Strategy::B, Strategy::C]);
}

#[test]
fn strategy_c_batch_round_trips_warm_with_zero_resolutions() {
    // Strategy (c) through the serve engine: the cold pass fits the
    // residual model and persists it; a fresh engine over the same
    // store serves every (c) cell from disk — identical bytes, zero
    // calibration resolutions, zero store misses.
    let tmp = TempDir::new("serve-warm-c").unwrap();
    let batch = QueryBatch::from_json(
        r#"[{"arch": "small", "strategy": "b,c", "threads": [1, 15, 240]}]"#,
    )
    .unwrap();
    assert_eq!(batch.cells(), 6);

    let lab = Lab::open(tmp.path()).unwrap();
    let first = PredictEngine::new(ParamSource::Paper, 1).with_store(Arc::clone(lab.store()));
    let rows_cold: Vec<String> = first
        .eval_batch(&batch)
        .unwrap()
        .iter()
        .flat_map(|q| q.rows())
        .map(|r| r.emit())
        .collect();
    assert_eq!(rows_cold.len(), 6);
    assert!(first.stats().calibration_resolutions > 0);
    // The engine rows match a serial reference sweep of the same grid.
    let grid = batch.queries[0].to_grid(ParamSource::Paper).unwrap();
    let reference = SweepRunner::serial().run(&grid).unwrap();
    assert_eq!(rows_cold, sweep_rows(&reference));

    let lab2 = Lab::open(tmp.path()).unwrap();
    let second = PredictEngine::new(ParamSource::Paper, 1).with_store(Arc::clone(lab2.store()));
    let rows_warm: Vec<String> = second
        .eval_batch(&batch)
        .unwrap()
        .iter()
        .flat_map(|q| q.rows())
        .map(|r| r.emit())
        .collect();
    assert_eq!(rows_warm, rows_cold);
    let stats = second.stats();
    assert_eq!(
        stats.calibration_resolutions, 0,
        "warm store must serve the (c) cells without refitting: {stats:?}"
    );
    let store = stats.store.expect("store attached");
    assert_eq!(store.misses, 0, "warm store must not miss: {store:?}");
}

#[test]
fn concurrent_identical_batches_resolve_each_pair_exactly_once() {
    // The single-flight contract on the serve hot path: N threads
    // firing the *same* batch at a cold engine race on identical
    // (arch, sim fingerprint) pairs, and every racer must coalesce
    // onto one in-flight resolution — the engine performs exactly D
    // calibration resolutions for D distinct pairs, not up to N × D.
    let text = r#"[{"arch": "small", "strategy": "both", "threads": [1, 15, 61, 240]},
                   {"arch": "medium", "strategy": "a", "threads": [15, 240]}]"#;
    let batch = QueryBatch::from_json(text).unwrap();
    let engine = PredictEngine::new(ParamSource::Paper, 2);
    let rows: Vec<Vec<String>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                scope.spawn(|| {
                    engine
                        .eval_batch(&batch)
                        .unwrap()
                        .iter()
                        .flat_map(|q| q.rows())
                        .map(|r| r.emit())
                        .collect::<Vec<String>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // Every concurrent caller got byte-identical rows.
    for r in &rows[1..] {
        assert_eq!(r, &rows[0]);
    }
    let stats = engine.stats();
    assert_eq!(stats.batches, 8);
    // Two distinct (arch, fingerprint) pairs → exactly two resolutions,
    // no matter that 8 batches raced on them concurrently.
    assert_eq!(stats.calibration_resolutions, 2, "{stats:?}");
}

/// Minimal HTTP/1.1 client: one request, read to EOF (the server
/// closes every connection), split off the body.
fn http(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> (String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut reply = String::new();
    stream.read_to_string(&mut reply).unwrap();
    let (head, body) = reply.split_once("\r\n\r\n").expect("full response");
    (head.to_string(), body.to_string())
}

#[test]
fn server_end_to_end_over_a_real_socket() {
    let engine = Arc::new(PredictEngine::new(ParamSource::Paper, 1));
    let server = Arc::new(Server::bind(Arc::clone(&engine), "127.0.0.1:0", 2).unwrap());
    let addr = server.local_addr().unwrap();
    let running = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || server.run())
    };

    // Liveness.
    let (head, body) = http(addr, "GET", "/healthz", "");
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert_eq!(body, "{\"ok\": true}");

    // Unknown path → 404.
    let (head, _) = http(addr, "GET", "/nope", "");
    assert!(head.starts_with("HTTP/1.1 404"), "{head}");

    // Protocol-level problems get explicit 4xx responses with an error
    // body, not a silently dropped connection.
    let raw = |req: String| -> String {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(req.as_bytes()).unwrap();
        let mut reply = String::new();
        stream.read_to_string(&mut reply).unwrap();
        reply
    };
    // POST without a Content-Length → 411.
    let reply = raw(format!("POST /predict HTTP/1.1\r\nHost: {addr}\r\n\r\n"));
    assert!(reply.starts_with("HTTP/1.1 411"), "{reply}");
    assert!(reply.contains("\"error\""), "{reply}");
    // Unparseable Content-Length → 400.
    let reply = raw(format!(
        "POST /predict HTTP/1.1\r\nHost: {addr}\r\nContent-Length: nope\r\n\r\n"
    ));
    assert!(reply.starts_with("HTTP/1.1 400"), "{reply}");
    // Body over the cap → 413 (nothing is read past the head).
    let reply = raw(format!(
        "POST /predict HTTP/1.1\r\nHost: {addr}\r\nContent-Length: 999999999999\r\n\r\n"
    ));
    assert!(reply.starts_with("HTTP/1.1 413"), "{reply}");

    // Malformed batch → 400 with an error body.
    let (head, body) = http(addr, "POST", "/predict", "{\"not\": \"a batch\"}");
    assert!(head.starts_with("HTTP/1.1 400"), "{head}");
    assert!(body.contains("\"error\""), "{body}");

    // A real batch → 200 with the same document the engine produces.
    let batch_text = r#"[{"arch": "small", "threads": [1, 15, 240]},
                         {"arch": "medium", "strategy": "a", "threads": [61]}]"#;
    let (head, body) = http(addr, "POST", "/predict", batch_text);
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    let batch = QueryBatch::from_json(batch_text).unwrap();
    let expected = predict_doc(&engine.eval_batch(&batch).unwrap(), &engine.stats()).emit();
    let got = Json::parse(&body).unwrap();
    let want = Json::parse(&expected).unwrap();
    assert_eq!(
        got.get("results").map(Json::emit),
        want.get("results").map(Json::emit),
        "served rows must be bit-identical to the engine's"
    );
    assert_eq!(got.get("cells").map(Json::emit), want.get("cells").map(Json::emit));

    // Stats accounting: the server served one batch (7 cells), the
    // direct eval_batch above added another on the shared engine.
    let (_, body) = http(addr, "GET", "/stats", "");
    let stats = Json::parse(&body).unwrap();
    assert_eq!(stats.get("batches").and_then(Json::as_usize), Some(2));
    assert_eq!(stats.get("queries").and_then(Json::as_usize), Some(4));
    assert_eq!(stats.get("cells").and_then(Json::as_usize), Some(14));

    // Graceful shutdown: acknowledged, then run() returns.
    let (head, _) = http(addr, "POST", "/shutdown", "");
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    running.join().unwrap().unwrap();
}
