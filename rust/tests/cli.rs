//! CLI surface tests: spawn the real `repro` binary per subcommand.

use std::process::{Command, Output};

fn repro(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .output()
        .expect("spawn repro")
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).to_string()
}

#[test]
fn no_args_prints_usage() {
    let out = repro(&[]);
    assert!(out.status.success());
    assert!(stdout(&out).contains("USAGE"));
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = repro(&["frobnicate"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("USAGE"));
}

#[test]
fn exp_table10_prints_paper_cells() {
    let out = repro(&["exp", "table10"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = stdout(&out);
    assert!(s.contains("480") && s.contains("3840"));
    assert!(s.contains("4.6")); // paper small-b @3840
}

#[test]
fn exp_csv_mode() {
    let out = repro(&["exp", "table9", "--csv"]);
    assert!(out.status.success());
    let s = stdout(&out);
    assert!(s.lines().next().unwrap().contains(','));
}

#[test]
fn exp_unknown_id_fails() {
    let out = repro(&["exp", "table99"]);
    assert!(!out.status.success());
}

#[test]
fn arch_lists_all_three() {
    let out = repro(&["arch"]);
    assert!(out.status.success());
    let s = stdout(&out);
    for name in ["small", "medium", "large"] {
        assert!(s.contains(name), "{name}");
    }
    assert!(s.contains("216100")); // large C3 weights (Fig. 2c)
}

#[test]
fn simulate_reports_phases() {
    let out = repro(&["simulate", "--arch", "small", "--threads", "240",
                      "--epochs", "2", "--images", "6000"]);
    assert!(out.status.success());
    let s = stdout(&out);
    assert!(s.contains("phases:") && s.contains("execution"));
}

#[test]
fn simulate_per_image_fidelity_small_workload() {
    let out = repro(&["simulate", "--arch", "small", "--threads", "8",
                      "--epochs", "1", "--images", "64", "--test-images", "8",
                      "--fidelity", "image"]);
    assert!(out.status.success());
    let s = stdout(&out);
    // Per-image mode reports its event count.
    assert!(s.contains("events"), "{s}");
    assert!(!s.contains("events 0"), "{s}");
}

#[test]
fn predict_both_strategies() {
    let out = repro(&["predict", "--arch", "medium", "--threads", "480"]);
    assert!(out.status.success());
    let s = stdout(&out);
    assert!(s.contains("minutes"));
    // Both strategies rendered.
    let rows = s.lines().filter(|l| l.starts_with("a ") || l.starts_with("b ")).count();
    assert_eq!(rows, 2, "{s}");
}

#[test]
fn probe_prints_eleven_rows() {
    let out = repro(&["probe", "--arch", "large"]);
    assert!(out.status.success());
    let s = stdout(&out);
    assert!(s.contains("3840"));
}

#[test]
fn train_engine_backend_tiny_run() {
    let out = repro(&["train", "--backend", "engine", "--arch", "small",
                      "--epochs", "1", "--images", "80", "--test-images", "20",
                      "--workers", "2"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = stdout(&out);
    assert!(s.contains("img/s"));
    assert!(s.contains("synthetic"));
}

#[test]
fn sweep_small_grid_renders_summary() {
    let out = repro(&["sweep", "--arch", "small", "--threads", "1,240",
                      "--strategy", "both", "--serial"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = stdout(&out);
    assert!(s.contains("sweep summary"), "{s}");
    assert!(s.contains("hit rate"), "{s}");
}

#[test]
fn sweep_full_table_has_one_row_per_scenario() {
    let out = repro(&["sweep", "--arch", "small,medium", "--threads", "60,240",
                      "--strategy", "a", "--serial", "--full"]);
    assert!(out.status.success());
    let s = stdout(&out);
    // 2 archs × 2 thread counts × 1 strategy.
    assert_eq!(s.lines().filter(|l| l.contains("60000")).count(), 4, "{s}");
}

#[test]
fn sweep_range_axis_and_json_output() {
    let dir = micdl::util::tmp::TempDir::new("cli-sweep").unwrap();
    let json_path = dir.path().join("sweep.json");
    let out = repro(&["sweep", "--arch", "small", "--threads", "1..16",
                      "--strategy", "both", "--json",
                      json_path.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let doc = micdl::util::json::Json::parse(
        &std::fs::read_to_string(&json_path).unwrap(),
    )
    .unwrap();
    assert_eq!(doc.get("scenarios").unwrap().as_usize(), Some(32));
    assert_eq!(doc.get("results").unwrap().as_arr().unwrap().len(), 32);
}

#[test]
fn sweep_csv_mode() {
    let out = repro(&["sweep", "--arch", "small", "--threads", "15",
                      "--strategy", "a", "--serial", "--csv"]);
    assert!(out.status.success());
    let s = stdout(&out);
    assert!(s.lines().next().unwrap().contains(','));
    assert_eq!(s.lines().count(), 2); // header + one scenario
}

#[test]
fn sweep_sim_ablation_grid_carries_variant_keys_and_cache_wins() {
    // The acceptance criterion: an ablation grid over simulator clocks
    // whose results[]/accuracy[] rows carry the sim-variant key, with a
    // cache hit rate at least that of the non-ablation equivalent.
    let dir = micdl::util::tmp::TempDir::new("cli-sweep-sim").unwrap();
    let json_path = dir.path().join("out.json");
    let plain_path = dir.path().join("plain.json");
    let out = repro(&["sweep", "--arch", "small", "--measure",
                      "--sim-clock-ghz", "1.0,1.238,1.5", "--serial",
                      "--json", json_path.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let doc = micdl::util::json::Json::parse(
        &std::fs::read_to_string(&json_path).unwrap(),
    )
    .unwrap();
    // 3 sim variants × the 14-cell small measured grid.
    assert_eq!(doc.get("scenarios").unwrap().as_usize(), Some(42));
    let rows = doc.get("results").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 42);
    for (i, row) in rows.iter().enumerate() {
        let sim = row.get("sim").unwrap().as_str().unwrap();
        let want = ["clock=1", "clock=1.238", "clock=1.5"][i / 14];
        assert_eq!(sim, want, "row {i}");
        assert!(row.get("measured_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(row.get("delta_pct").is_some());
    }
    let acc = doc.get("accuracy").unwrap().as_arr().unwrap();
    assert_eq!(acc.len(), 6); // 3 variants × 2 strategies
    for a in acc {
        assert!(a.get("sim").unwrap().as_str().is_some());
    }
    // Hit rate: per-variant sharing is identical to the non-ablation
    // grid's, so the whole-run rate must not fall below it.
    let out = repro(&["sweep", "--arch", "small", "--measure", "--serial",
                      "--json", plain_path.to_str().unwrap()]);
    assert!(out.status.success());
    let plain = micdl::util::json::Json::parse(
        &std::fs::read_to_string(&plain_path).unwrap(),
    )
    .unwrap();
    let rate = |d: &micdl::util::json::Json| {
        let c = d.get("cache").unwrap();
        let h = c.get("hits").unwrap().as_f64().unwrap();
        let m = c.get("misses").unwrap().as_f64().unwrap();
        h / (h + m)
    };
    assert!(
        rate(&doc) >= rate(&plain) - 1e-12,
        "ablation hit rate {} < plain {}",
        rate(&doc),
        rate(&plain)
    );
}

#[test]
fn sweep_sim_override_beats_machine_axis_with_warning() {
    // The composition bugfix: --clock-ghz with a disagreeing
    // --sim-clock-ghz warns (sim wins) instead of silently dropping one.
    let out = repro(&["sweep", "--arch", "small", "--threads", "15",
                      "--strategy", "a", "--serial", "--measure",
                      "--clock-ghz", "1.0", "--sim-clock-ghz", "1.5", "--full"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("warning:") && stderr.contains("wins"), "{stderr}");
    // Agreement produces no warning.
    let out = repro(&["sweep", "--arch", "small", "--threads", "15",
                      "--strategy", "a", "--serial",
                      "--sim-seed", "7"]);
    assert!(out.status.success());
    assert!(!String::from_utf8_lossy(&out.stderr).contains("warning:"));
}

#[test]
fn sweep_rejects_bad_sim_flags() {
    let out = repro(&["sweep", "--sim-clock-ghz", "fast"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("wants floats"));
    let out = repro(&["sweep", "--sim-fidelity", "quantum"]);
    assert!(!out.status.success());
    let out = repro(&["sweep", "--sim-clock-ghz"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("needs a value"));
    let out = repro(&["sweep", "--sim-clokc-ghz", "1.0"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown sweep flag"));
}

#[test]
fn sweep_rejects_bad_axis() {
    // A reversed range is a config error naming the problem — never a
    // silent 0-cell grid that "succeeds" while sweeping nothing.
    let out = repro(&["sweep", "--threads", "240..1"]);
    assert_eq!(out.status.code(), Some(1));
    let e = String::from_utf8_lossy(&out.stderr).to_string();
    assert!(e.contains("config error"), "{e}");
    assert!(e.contains("below range start"), "{e}");
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).to_string()
}

#[test]
fn sweep_noun_verb_and_legacy_spellings() {
    // The canonical spelling: no deprecation note.
    let out = repro(&["sweep", "run", "--arch", "small", "--threads", "15",
                      "--strategy", "a", "--serial"]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(!stderr(&out).contains("deprecated:"), "{}", stderr(&out));
    assert!(stdout(&out).contains("sweep summary"));
    // The verbless legacy spelling still works, with one deprecation note.
    let out = repro(&["sweep", "--arch", "small", "--threads", "15",
                      "--strategy", "a", "--serial"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let e = stderr(&out);
    assert!(e.contains("deprecated:") && e.contains("sweep run"), "{e}");
    // Unknown verbs are rejected, not silently treated as legacy mode.
    let out = repro(&["sweep", "frobnicate"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("unknown sweep verb"), "{}", stderr(&out));
}

#[test]
fn sweep_baseline_verbs_match_legacy_flags() {
    let dir = micdl::util::tmp::TempDir::new("cli-baseline").unwrap();
    let path = dir.path().join("base.json");
    let p = path.to_str().unwrap();
    let grid = ["--arch", "small", "--threads", "15,61", "--strategy", "a", "--serial"];
    // Noun-verb write…
    let mut args = vec!["sweep", "baseline", "write", p];
    args.extend_from_slice(&grid);
    let out = repro(&args);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(path.exists());
    // …checked by the noun-verb compare (clean → exit 0)…
    let mut args = vec!["sweep", "baseline", "compare", p];
    args.extend_from_slice(&grid);
    let out = repro(&args);
    assert!(out.status.success(), "{}", stderr(&out));
    assert_eq!(out.status.code(), Some(0));
    // …and by the legacy flag spelling, which still works.
    let mut args = vec!["sweep", "--compare", p];
    args.extend_from_slice(&grid);
    let out = repro(&args);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stderr(&out).contains("deprecated:"), "{}", stderr(&out));
}

#[test]
fn sweep_lab_second_pass_is_pure_store_hits_with_identical_payload() {
    // The acceptance criterion behind the CI two-pass smoke: an identical
    // measured sweep against a warm lab performs zero recomputation
    // (store misses 0) and emits the same grid/results/accuracy payload.
    let dir = micdl::util::tmp::TempDir::new("cli-lab").unwrap();
    let lab = dir.path().join("lab");
    let cold_json = dir.path().join("cold.json");
    let warm_json = dir.path().join("warm.json");
    let run = |json: &std::path::Path| {
        repro(&["sweep", "run", "--arch", "small", "--threads", "1,15",
                "--strategy", "both", "--measure", "--serial",
                "--lab", lab.to_str().unwrap(),
                "--json", json.to_str().unwrap()])
    };
    let out = run(&cold_json);
    assert!(out.status.success(), "{}", stderr(&out));
    let out = run(&warm_json);
    assert!(out.status.success(), "{}", stderr(&out));
    let parse = |p: &std::path::Path| {
        micdl::util::json::Json::parse(&std::fs::read_to_string(p).unwrap()).unwrap()
    };
    let (cold, warm) = (parse(&cold_json), parse(&warm_json));
    let store = warm.get("store").unwrap();
    assert_eq!(store.get("misses").unwrap().as_usize(), Some(0), "{store:?}");
    assert_eq!(store.get("hits").unwrap().as_usize(), Some(4), "{store:?}");
    for key in ["grid", "results", "accuracy", "scenarios"] {
        assert_eq!(
            cold.get(key).unwrap().emit(),
            warm.get(key).unwrap().emit(),
            "{key} differs between cold and warm pass"
        );
    }
}

#[test]
fn sweep_resume_and_no_store_flags() {
    let dir = micdl::util::tmp::TempDir::new("cli-resume").unwrap();
    let lab = dir.path().join("lab");
    let base = ["--arch", "small", "--threads", "15", "--strategy", "a", "--serial"];
    // --resume/--no-store are meaningless without --lab.
    let mut args = vec!["sweep", "run", "--resume"];
    args.extend_from_slice(&base);
    let out = repro(&args);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("requires --lab"), "{}", stderr(&out));
    // First --resume: nothing to resume, runs fresh.
    let mut args = vec!["sweep", "run", "--lab", lab.to_str().unwrap(), "--resume"];
    args.extend_from_slice(&base);
    let out = repro(&args);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stderr(&out).contains("starting fresh"), "{}", stderr(&out));
    // Second --resume: reports the manifest it resumes.
    let out = repro(&args);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stderr(&out).contains("resuming run"), "{}", stderr(&out));
    // --no-store bypasses the lab entirely: no store telemetry.
    let json = dir.path().join("nostore.json");
    let mut args = vec!["sweep", "run", "--lab", lab.to_str().unwrap(), "--no-store",
                        "--json", json.to_str().unwrap()];
    args.extend_from_slice(&base);
    let out = repro(&args);
    assert!(out.status.success(), "{}", stderr(&out));
    let doc = micdl::util::json::Json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
    assert!(doc.get("store").is_none());
}

#[test]
fn lab_verbs_list_gc_trace_params() {
    let dir = micdl::util::tmp::TempDir::new("cli-lab-verbs").unwrap();
    let lab = dir.path().join("lab");
    let lab_s = lab.to_str().unwrap();
    let out = repro(&["sweep", "run", "--arch", "small", "--threads", "15",
                      "--strategy", "a", "--serial", "--lab", lab_s]);
    assert!(out.status.success(), "{}", stderr(&out));
    // list: one completed run manifest.
    let out = repro(&["lab", "list", "--lab", lab_s]);
    assert!(out.status.success(), "{}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("lab runs — 1") && s.contains("complete"), "{s}");
    // Top-level alias prints the same listing.
    let out = repro(&["list", "--lab", lab_s]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert_eq!(stdout(&out), s);
    // gc on a healthy store removes nothing.
    let out = repro(&["lab", "gc", "--dry-run", "--lab", lab_s]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("(dry run)"), "{}", stdout(&out));
    assert!(stdout(&out).contains("removed 0"), "{}", stdout(&out));
    let out = repro(&["gc", "--lab", lab_s]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("removed 0"), "{}", stdout(&out));
    // trace-params prints the persisted calibration entry with its key.
    let out = repro(&["lab", "trace-params", "--arch", "small", "--lab", lab_s]);
    assert!(out.status.success(), "{}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("params:v1:small:paper:") && s.contains("calibrator"), "{s}");
    // Nothing persisted for the sim source yet → exit 1 with a message.
    let out = repro(&["trace-params", "--arch", "small", "--params", "sim",
                      "--lab", lab_s]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("no persisted calibration"), "{}", stderr(&out));
    // Verb validation.
    let out = repro(&["lab", "frobnicate", "--lab", lab_s]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("unknown lab verb"), "{}", stderr(&out));
    let out = repro(&["lab", "--lab", lab_s]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("needs a verb"), "{}", stderr(&out));
}

#[test]
fn sweep_shard_flag_validation() {
    let dir = micdl::util::tmp::TempDir::new("cli-shard-flags").unwrap();
    let lab = dir.path().join("lab");
    let lab_s = lab.to_str().unwrap();
    let base = ["--arch", "small", "--threads", "15", "--strategy", "a", "--serial"];
    let run = |extra: &[&str]| {
        let mut args = vec!["sweep", "run"];
        args.extend_from_slice(extra);
        args.extend_from_slice(&base);
        repro(&args)
    };
    // Shards compose through a shared store, so --lab is mandatory…
    let out = run(&["--shard", "1/2"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("require --lab"), "{}", stderr(&out));
    // …and --no-store (which drops the store) is as bad as no --lab.
    let out = run(&["--shard", "1/2", "--lab", lab_s, "--no-store"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("require --lab"), "{}", stderr(&out));
    // Shard grammar: K/N, integers, 1-based K in range.
    let out = run(&["--shard", "2", "--lab", lab_s]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("wants K/N"), "{}", stderr(&out));
    let out = run(&["--shard", "x/y", "--lab", lab_s]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("wants integers"), "{}", stderr(&out));
    for bad in ["0/2", "3/2"] {
        let out = run(&["--shard", bad, "--lab", lab_s]);
        assert!(!out.status.success());
        assert!(stderr(&out).contains("1-based"), "{bad}: {}", stderr(&out));
    }
    // A worker is one shard xor the driver; partial grids cannot pin or
    // check baselines; --continue-on-failure is driver-only.
    let out = run(&["--shard", "1/2", "--shards", "2", "--lab", lab_s]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("mutually exclusive"), "{}", stderr(&out));
    let out = run(&["--shard", "1/2", "--lab", lab_s, "--write-baseline", "b.json"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("partial grid"), "{}", stderr(&out));
    let out = run(&["--continue-on-failure"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("only applies"), "{}", stderr(&out));
}

#[test]
fn sweep_shard_children_compose_and_lab_list_groups_them() {
    let dir = micdl::util::tmp::TempDir::new("cli-shard-child").unwrap();
    let lab = dir.path().join("lab");
    let lab_s = lab.to_str().unwrap();
    let grid = ["--arch", "small", "--threads", "1,15,61", "--strategy", "both",
                "--serial", "--lab"];
    let shard = |spec: &str, resume: bool| {
        let mut args = vec!["sweep", "run", "--shard", spec];
        if resume {
            args.push("--resume");
        }
        args.extend_from_slice(&grid);
        args.push(lab_s);
        repro(&args)
    };
    // Two shards of the 6-cell grid: 3 scenarios each, disjoint.
    let out = shard("1/2", false);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("3 scenarios"), "{}", stdout(&out));
    let out = shard("2/2", false);
    assert!(out.status.success(), "{}", stderr(&out));
    // --resume composes with --shard via the derived manifest id.
    let out = shard("1/2", true);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stderr(&out).contains("resuming shard run"), "{}", stderr(&out));
    assert!(stderr(&out).contains(".1of2"), "{}", stderr(&out));
    // The listing groups shard manifests (indented) under the parent id.
    let out = repro(&["lab", "list", "--lab", lab_s]);
    assert!(out.status.success(), "{}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("lab runs — 2"), "{s}");
    assert!(s.contains("└") && s.contains(".1of2") && s.contains(".2of2"), "{s}");
    // The shards covered the whole grid: a full run over the same lab
    // is pure store hits.
    let json = dir.path().join("full.json");
    let mut args = vec!["sweep", "run", "--json", json.to_str().unwrap()];
    args.extend_from_slice(&grid);
    args.push(lab_s);
    let out = repro(&args);
    assert!(out.status.success(), "{}", stderr(&out));
    let doc = micdl::util::json::Json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
    assert_eq!(doc.get("scenarios").unwrap().as_usize(), Some(6));
    let store = doc.get("store").unwrap();
    assert_eq!(store.get("misses").unwrap().as_usize(), Some(0), "{store:?}");
}

#[test]
fn sweep_shards_driver_output_matches_unsharded() {
    // The acceptance criterion: the --shards driver's stdout and stable
    // JSON payload are byte-identical to the unsharded run's.
    let dir = micdl::util::tmp::TempDir::new("cli-shard-driver").unwrap();
    let grid = ["--arch", "small", "--threads", "1,15,61", "--strategy", "both",
                "--serial", "--csv"];
    let run = |extra: &[&str], lab: &str, json: &std::path::Path| {
        let mut args = vec!["sweep", "run"];
        args.extend_from_slice(extra);
        args.extend_from_slice(&grid);
        args.extend_from_slice(&["--json", json.to_str().unwrap(), "--lab", lab]);
        repro(&args)
    };
    let whole_json = dir.path().join("whole.json");
    let whole_lab = dir.path().join("lab-whole");
    let whole = run(&[], whole_lab.to_str().unwrap(), &whole_json);
    assert!(whole.status.success(), "{}", stderr(&whole));
    let sharded_json = dir.path().join("sharded.json");
    let sharded_lab = dir.path().join("lab-sharded");
    let sharded = run(&["--shards", "3"], sharded_lab.to_str().unwrap(), &sharded_json);
    assert!(sharded.status.success(), "{}", stderr(&sharded));
    for k in 1..=3 {
        assert!(
            stderr(&sharded).contains(&format!("shard {k}/3 complete")),
            "{}",
            stderr(&sharded)
        );
    }
    // CSV table on stdout: byte-identical (it carries no telemetry).
    assert_eq!(stdout(&whole), stdout(&sharded));
    // JSON payload: stable keys byte-identical; wall/cache/store are
    // per-run telemetry and excluded, as in the CI lab smoke.
    let parse = |p: &std::path::Path| {
        micdl::util::json::Json::parse(&std::fs::read_to_string(p).unwrap()).unwrap()
    };
    let (w, s) = (parse(&whole_json), parse(&sharded_json));
    for key in ["grid", "results", "accuracy", "scenarios"] {
        assert_eq!(
            w.get(key).unwrap().emit(),
            s.get(key).unwrap().emit(),
            "{key} differs between unsharded and sharded driver run"
        );
    }
    // The driver's lab holds the parent manifest (complete) plus one
    // manifest per shard.
    let out = repro(&["lab", "list", "--lab", sharded_lab.to_str().unwrap()]);
    assert!(out.status.success(), "{}", stderr(&out));
    let listing = stdout(&out);
    assert!(listing.contains("lab runs — 4"), "{listing}");
    assert!(listing.contains(".1of3") && listing.contains(".3of3"), "{listing}");
}

#[test]
fn sweep_shards_driver_retries_and_reports_failures() {
    // threads=0 parses in the driver but fails grid validation inside
    // every child with a config error — a deterministic failure, so the
    // driver must classify it non-retryable and burn exactly ONE
    // attempt per shard instead of exhausting the 3-attempt budget on
    // an outcome that cannot change.
    let dir = micdl::util::tmp::TempDir::new("cli-shard-fail").unwrap();
    let lab = dir.path().join("lab");
    let run = |extra: &[&str]| {
        let mut args = vec!["sweep", "run", "--shards", "2",
                            "--arch", "small", "--threads", "0,15",
                            "--strategy", "a", "--serial", "--lab",
                            lab.to_str().unwrap()];
        args.extend_from_slice(extra);
        repro(&args)
    };
    // Fail-fast (default): exit 1 on the first wave, attempt counts
    // pinned — attempt 1 is announced as final, attempts 2 and 3 never
    // happen, and the child's error line is in the message.
    let out = run(&[]);
    assert_eq!(out.status.code(), Some(1));
    let e = stderr(&out);
    assert!(e.contains("attempt 1/3") && e.contains("non-retryable"), "{e}");
    assert!(!e.contains("attempt 2/3") && !e.contains("attempt 3/3"), "{e}");
    assert!(e.contains("failed with a non-retryable error"), "{e}");
    assert!(e.contains("thread counts must be >= 1"), "{e}");
    // --continue-on-failure: every shard is tried (once each — still no
    // retries) and the per-shard failure report covers them all,
    // classified; still exit 1.
    let out = run(&["--continue-on-failure"]);
    assert_eq!(out.status.code(), Some(1));
    let e = stderr(&out);
    assert!(e.contains("shard failure report"), "{e}");
    assert!(e.contains("shard 1/2") && e.contains("shard 2/2"), "{e}");
    assert!(e.contains("non-retryable"), "{e}");
    assert!(!e.contains("attempt 2/3"), "{e}");
}

#[test]
fn predict_batch_json_matches_sweep_dump_rows() {
    let dir = micdl::util::tmp::TempDir::new("cli-predict").unwrap();
    let batch = dir.path().join("batch.json");
    std::fs::write(
        &batch,
        r#"[{"arch": "small", "strategy": "a", "threads": [1, 15, 61, 240]}]"#,
    )
    .unwrap();
    let out_path = dir.path().join("predict.json");
    let out = repro(&["predict", "--batch", batch.to_str().unwrap(),
                      "--json", out_path.to_str().unwrap(), "--serial"]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stderr(&out).contains("calibration resolutions: 1"), "{}", stderr(&out));
    let doc = micdl::util::json::Json::parse(&std::fs::read_to_string(&out_path).unwrap())
        .unwrap();
    assert_eq!(doc.get("queries").and_then(|j| j.as_f64()), Some(1.0));
    assert_eq!(doc.get("cells").and_then(|j| j.as_f64()), Some(4.0));
    let rows = doc.get("results").and_then(|j| j.as_arr()).unwrap();
    assert_eq!(rows.len(), 4);

    // The predict rows are byte-identical to the dump of the sweep the
    // batch abbreviates.
    let sweep_json = dir.path().join("sweep.json");
    let out = repro(&["sweep", "run", "--arch", "small", "--strategy", "a",
                      "--threads", "1,15,61,240", "--serial",
                      "--json", sweep_json.to_str().unwrap()]);
    assert!(out.status.success(), "{}", stderr(&out));
    let sweep = micdl::util::json::Json::parse(&std::fs::read_to_string(&sweep_json).unwrap())
        .unwrap();
    let sweep_rows = sweep.get("results").and_then(|j| j.as_arr()).unwrap();
    let emit = |rs: &[micdl::util::json::Json]| -> Vec<String> {
        rs.iter().map(|r| r.emit()).collect()
    };
    assert_eq!(emit(rows), emit(sweep_rows));
}

#[test]
fn predict_batch_csv_and_table_modes() {
    let dir = micdl::util::tmp::TempDir::new("cli-predict-csv").unwrap();
    let batch = dir.path().join("batch.json");
    std::fs::write(
        &batch,
        r#"{"queries": [{"arch": "small", "threads": [15, 240]},
                        {"arch": "medium", "strategy": "b", "threads": [61]}]}"#,
    )
    .unwrap();
    let bp = batch.to_str().unwrap();
    // CSV: one header line, then 2×2 + 1 data rows across both queries.
    let out = repro(&["predict", "--batch", bp, "--csv", "--serial"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let csv = stdout(&out);
    assert_eq!(csv.lines().count(), 1 + 5, "{csv}");
    assert!(csv.lines().next().unwrap().contains(','), "{csv}");
    // Default: human tables plus the engine-stats footer.
    let out = repro(&["predict", "--batch", bp, "--serial"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("2 queries in 1 batches, 5 cells"), "{s}");
    // A batch mixing sim and non-sim queries has two column sets (the
    // sim query gains a leading `sim` column): the stream re-emits the
    // header at the switch so every row aligns with its header.
    let mixed = dir.path().join("mixed.json");
    std::fs::write(
        &mixed,
        r#"[{"arch": "small", "strategy": "a", "threads": [15]},
            {"arch": "small", "strategy": "a", "threads": [15], "sim": {"clock_ghz": 1.5}},
            {"arch": "small", "strategy": "a", "threads": [61], "sim": {"clock_ghz": 1.5}}]"#,
    )
    .unwrap();
    let out = repro(&["predict", "--batch", mixed.to_str().unwrap(), "--csv", "--serial"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let csv = stdout(&out);
    let lines: Vec<&str> = csv.lines().collect();
    // header, row, sim header, sim row, sim row (repeat header skipped).
    assert_eq!(lines.len(), 5, "{csv}");
    let cols = |l: &str| l.split(',').count();
    assert!(lines[0].starts_with("arch"), "{csv}");
    assert!(lines[2].starts_with("sim"), "{csv}");
    assert_eq!(cols(lines[0]), cols(lines[1]), "{csv}");
    assert_eq!(cols(lines[2]), cols(lines[0]) + 1, "{csv}");
    assert_eq!(cols(lines[3]), cols(lines[2]), "{csv}");
    assert_eq!(cols(lines[4]), cols(lines[2]), "{csv}");
    // --json and --csv together are rejected.
    let out = repro(&["predict", "--batch", bp, "--csv", "--json", "x.json"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("mutually exclusive"), "{}", stderr(&out));
}

#[test]
fn predict_batch_rejects_reversed_thread_ranges() {
    // The silent-empty-axis bugfix, through the predict surface.
    let dir = micdl::util::tmp::TempDir::new("cli-predict-bad").unwrap();
    let batch = dir.path().join("batch.json");
    std::fs::write(
        &batch,
        r#"[{"arch": "small", "threads_range": {"from": 30, "to": 10}}]"#,
    )
    .unwrap();
    let out = repro(&["predict", "--batch", batch.to_str().unwrap()]);
    assert_eq!(out.status.code(), Some(1));
    let e = stderr(&out);
    assert!(e.contains("config error"), "{e}");
    assert!(e.contains("below range start"), "{e}");
}

#[test]
fn sweep_and_predict_accept_strategy_c() {
    // `--strategy c` (and the a,b,c shorthands) sweep the residual
    // regressor end-to-end through the ordinary grid machinery.
    let dir = micdl::util::tmp::TempDir::new("cli-strategy-c").unwrap();
    let json_path = dir.path().join("c.json");
    let out = repro(&["sweep", "run", "--arch", "small", "--threads", "15,240",
                      "--strategy", "all", "--serial", "--json",
                      json_path.to_str().unwrap()]);
    assert!(out.status.success(), "{}", stderr(&out));
    let doc = micdl::util::json::Json::parse(
        &std::fs::read_to_string(&json_path).unwrap(),
    )
    .unwrap();
    // 2 thread counts × 3 strategies.
    assert_eq!(doc.get("scenarios").unwrap().as_usize(), Some(6));
    let rows = doc.get("results").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 6);
    assert!(
        rows.iter().any(|r| r.get("strategy").map(|s| s.emit()) == Some("\"c\"".into())),
        "{}",
        doc.emit()
    );
    // Single-point predict renders one row per strategy, (c) included.
    let out = repro(&["predict", "--arch", "small", "--threads", "240",
                      "--strategy", "all"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let s = stdout(&out);
    let rows = s
        .lines()
        .filter(|l| l.starts_with("a ") || l.starts_with("b ") || l.starts_with("c "))
        .count();
    assert_eq!(rows, 3, "{s}");
}

#[test]
fn strategy_grammar_is_shared_across_all_three_surfaces() {
    // One grammar, one message: CLI flags, JSON sweep specs, and predict
    // batch queries accept and reject strategy tokens identically.
    let dir = micdl::util::tmp::TempDir::new("cli-strategy-grammar").unwrap();
    let want = "strategy must be a|b|c|both, got \"z\"";
    // 1. The CLI flag.
    let out = repro(&["sweep", "run", "--arch", "small", "--threads", "15",
                      "--strategy", "z", "--serial"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains(want), "{}", stderr(&out));
    // 2. The JSON sweep spec.
    let spec = dir.path().join("spec.json");
    std::fs::write(
        &spec,
        r#"{"archs": ["small"], "threads": [15], "strategies": ["z"]}"#,
    )
    .unwrap();
    let out = repro(&["sweep", "run", "--spec", spec.to_str().unwrap(), "--serial"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains(want), "{}", stderr(&out));
    std::fs::write(
        &spec,
        r#"{"archs": ["small"], "threads": [15], "strategies": ["b", "c"]}"#,
    )
    .unwrap();
    let out = repro(&["sweep", "run", "--spec", spec.to_str().unwrap(), "--serial"]);
    assert!(out.status.success(), "{}", stderr(&out));
    // 3. The predict batch schema (shared with POST /predict).
    let batch = dir.path().join("batch.json");
    std::fs::write(&batch, r#"[{"arch": "small", "strategy": "z", "threads": [15]}]"#)
        .unwrap();
    let out = repro(&["predict", "--batch", batch.to_str().unwrap(), "--serial"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains(want), "{}", stderr(&out));
    std::fs::write(&batch, r#"[{"arch": "small", "strategy": "c", "threads": [15]}]"#)
        .unwrap();
    let out = repro(&["predict", "--batch", batch.to_str().unwrap(), "--serial", "--csv"]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert_eq!(stdout(&out).lines().count(), 2, "{}", stdout(&out)); // header + (c) row
}

#[test]
fn selfcheck_passes() {
    let out = repro(&["selfcheck"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout(&out).contains("selfcheck OK"));
}
