//! Cross-module integration tests: dataset → engine → coordinator,
//! simulator ↔ perfmodel consistency, experiments end-to-end.

use micdl::config::{ArchSpec, RunConfig};
use micdl::coordinator::pool::{DataParallelTrainer, PoolConfig};
use micdl::dataset;
use micdl::experiments::{self, ExpOptions};
use micdl::nn::opcount;
use micdl::perfmodel::{both_models, delta_pct, ParamSource, PerfModel};
use micdl::simulator::{probe, simulate_training, SimConfig};

// ---------------------------------------------------------------------------
// Simulator ↔ model consistency
// ---------------------------------------------------------------------------

#[test]
fn models_predict_simulator_within_band_all_archs() {
    // The headline reproduction claim (Table IX): both analytic models
    // predict the "machine" (micsim) within the paper's accuracy band.
    let cfg = SimConfig::default();
    for arch in ArchSpec::paper_archs() {
        let (a, b) = both_models(&arch, ParamSource::Paper).unwrap();
        let mut worst_a = 0.0f64;
        let mut worst_b = 0.0f64;
        for &p in RunConfig::MEASURED_THREADS.iter() {
            let run = RunConfig::paper_default(&arch.name, p);
            let m = probe::measured_execution_s(&arch, p, &cfg).unwrap();
            worst_a = worst_a.max(delta_pct(m, a.predict(&run).unwrap().total_s));
            worst_b = worst_b.max(delta_pct(m, b.predict(&run).unwrap().total_s));
        }
        assert!(worst_a < 30.0, "{}: worst Δa {worst_a:.1}%", arch.name);
        assert!(worst_b < 30.0, "{}: worst Δb {worst_b:.1}%", arch.name);
    }
}

#[test]
fn simulator_scaling_shape_matches_figures() {
    // Figs. 5-7 shape: time falls steeply to 120 threads, then flattens;
    // at 240 threads the speedup over 1 thread is large but sublinear.
    let cfg = SimConfig::default();
    for arch in ArchSpec::paper_archs() {
        let t = |p: usize| probe::measured_execution_s(&arch, p, &cfg).unwrap();
        let t1 = t(1);
        let t120 = t(120);
        let t240 = t(240);
        assert!(t120 < t1 / 40.0, "{}: t1 {t1} t120 {t120}", arch.name);
        assert!(t240 < t120 * 1.5, "{}: flattening violated", arch.name);
        let speedup = t1 / t240;
        assert!(speedup > 30.0 && speedup < 240.0, "{}: {speedup}", arch.name);
    }
}

#[test]
fn contention_source_consistency_models_vs_probe() {
    // Under ParamSource::Simulator both models use the probe's contention;
    // predictions must stay finite and ordered in p.
    for arch in ArchSpec::paper_archs() {
        let (a, _) = both_models(&arch, ParamSource::Simulator).unwrap();
        let mut prev = f64::INFINITY;
        for p in [15, 60, 120] {
            let run = RunConfig::paper_default(&arch.name, p);
            let t = a.predict(&run).unwrap().total_s;
            assert!(t.is_finite() && t < prev, "{} p={p}", arch.name);
            prev = t;
        }
    }
}

// ---------------------------------------------------------------------------
// Dataset → engine → coordinator
// ---------------------------------------------------------------------------

#[test]
fn full_engine_training_pipeline() {
    let (train, test) = dataset::load_or_synth(None, 300, 60, 99);
    assert_eq!(train.source, "synthetic");
    let cfg = PoolConfig { workers: 4, epochs: 8, lr: 0.02, eval_cap: 60, seed: 5, verbose: false };
    let mut trainer = DataParallelTrainer::new(ArchSpec::small(), cfg).unwrap();
    let report = trainer.train(&train, &test).unwrap();
    assert!(report.converging());
    // A learnable corpus: the small CNN must beat chance (10%) clearly.
    assert!(
        report.final_test_accuracy() > 0.3,
        "final accuracy {:.3}",
        report.final_test_accuracy()
    );
    assert!(report.train_throughput > 0.0);
}

#[test]
fn engine_training_deterministic_given_seed_and_single_worker() {
    let (train, test) = dataset::load_or_synth(None, 60, 10, 3);
    let run = |seed| {
        let cfg = PoolConfig { workers: 1, epochs: 2, lr: 0.02, eval_cap: 10, seed, verbose: false };
        let mut t = DataParallelTrainer::new(ArchSpec::small(), cfg).unwrap();
        t.train(&train, &test).unwrap().epochs.last().unwrap().train_loss
    };
    assert_eq!(run(7).to_bits(), run(7).to_bits());
    assert_ne!(run(7).to_bits(), run(8).to_bits());
}

#[test]
fn worker_count_does_not_change_image_coverage() {
    // Different worker counts shard differently but must train on every
    // image exactly once per epoch (metrics count them).
    let (train, test) = dataset::load_or_synth(None, 120, 10, 4);
    for workers in [1, 3, 8] {
        let cfg = PoolConfig { workers, epochs: 2, lr: 0.01, eval_cap: 8, seed: 1, verbose: false };
        let mut t = DataParallelTrainer::new(ArchSpec::small(), cfg).unwrap();
        t.train(&train, &test).unwrap();
        assert_eq!(t.metrics.images_trained, 240, "workers={workers}");
    }
}

// ---------------------------------------------------------------------------
// Op counts feed both the models and the simulator coherently
// ---------------------------------------------------------------------------

#[test]
fn opcounts_consistent_across_consumers() {
    for arch in ArchSpec::paper_archs() {
        let computed = opcount::count(&arch).unwrap();
        let per_layer = opcount::layer_ops(&arch).unwrap();
        let fwd_sum: u64 = per_layer.iter().map(|l| l.fwd).sum();
        assert_eq!(fwd_sum, computed.fprop.total());
        // Paper counts exist for the three paper archs.
        let paper = opcount::resolve(&arch, micdl::nn::OpSource::Paper).unwrap();
        assert!(paper.fprop.total() > 0);
    }
}

// ---------------------------------------------------------------------------
// Experiments end-to-end (the CLI surface)
// ---------------------------------------------------------------------------

#[test]
fn all_experiments_render_with_paper_values_inline() {
    let out = experiments::run("all", &ExpOptions::default()).unwrap();
    // Spot-check one published anchor per artifact class.
    assert!(out.contains("ASCI Red"));            // fig1
    assert!(out.contains("1.40e-2"));             // table4 anchor (small@240)
    assert!(out.contains("9.64"));                // table7 ratio
    assert!(out.contains("11.96"));               // table8 ratio
    assert!(out.contains("14.57"));               // table9 paper Δ
    assert!(out.contains("4.6"));                 // table10 small@3840
    assert!(out.contains("139.3"));               // table11 corner
}

#[test]
fn experiments_csv_mode_all_ids() {
    let opts = ExpOptions { csv: true, ..Default::default() };
    for id in experiments::ALL_WITH_SCALING {
        let out = experiments::run(id, &opts).unwrap();
        let lines: Vec<&str> = out.lines().collect();
        assert!(lines.len() >= 2, "{id}");
        let header_cols = lines[0].split(',').count();
        for line in &lines[1..] {
            assert_eq!(line.split(',').count(), header_cols, "{id}: ragged CSV");
        }
    }
}

// ---------------------------------------------------------------------------
// Simulated hardware variants (ablation)
// ---------------------------------------------------------------------------

#[test]
fn faster_clock_means_faster_simulation() {
    let arch = ArchSpec::medium();
    let run = RunConfig::paper_default("medium", 240);
    let base = SimConfig::default();
    let mut fast = SimConfig::default();
    fast.machine.clock_hz *= 2.0;
    let t_base = simulate_training(&arch, &run, &base).unwrap().execution_s;
    let t_fast = simulate_training(&arch, &run, &fast).unwrap().execution_s;
    assert!(t_fast < t_base);
}

#[test]
fn disabling_smt_penalty_speeds_up_240_threads() {
    let arch = ArchSpec::medium();
    let run = RunConfig::paper_default("medium", 240);
    let base = SimConfig::default();
    let mut no_smt = SimConfig::default();
    no_smt.machine.cpi_ladder = vec![1.0, 1.0, 1.0, 1.0];
    let t_base = simulate_training(&arch, &run, &base).unwrap().execution_s;
    let t_flat = simulate_training(&arch, &run, &no_smt).unwrap().execution_s;
    assert!(t_flat < t_base);
}

#[test]
fn more_memory_channels_reduce_contention_effect() {
    let arch = ArchSpec::large();
    let cfg = SimConfig::default();
    let mut wide = SimConfig::default();
    wide.machine.memory_bw_bytes *= 4.0;
    let c_base = probe::contention_probe(&arch, 240, &cfg).unwrap();
    let c_wide = probe::contention_probe(&arch, 240, &wide).unwrap();
    assert!(c_wide < c_base);
}
