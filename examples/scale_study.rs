//! Scale study (the paper's Result 2): model-driven exploration beyond
//! the hardware thread count, cross-checked against micsim's
//! oversubscription model.
//!
//! Reproduces the reasoning behind Tables X/XI — how far does CNN
//! training on a MIC processor keep scaling? — and adds what the paper
//! could not measure: the simulator's view of 480–3,840 threads.
//!
//! Run: `cargo run --release --example scale_study`

use micdl::config::{ArchSpec, RunConfig};
use micdl::perfmodel::{both_models, ParamSource, PerfModel};
use micdl::report::Table;
use micdl::simulator::{probe, SimConfig};

fn main() -> micdl::Result<()> {
    let cfg = SimConfig::default();
    let threads: Vec<usize> = vec![60, 120, 240, 480, 960, 1920, 3840];

    for arch in ArchSpec::paper_archs() {
        let (model_a, model_b) = both_models(&arch, ParamSource::Paper)?;
        let mut t = Table::new(
            format!("scaling {} CNN (minutes)", arch.name),
            &["threads", "model (a)", "model (b)", "micsim", "speedup vs 60T (sim)"],
        );
        let base = probe::measured_execution_s(&arch, 60, &cfg)?;
        for &p in &threads {
            let run = RunConfig::paper_default(&arch.name, p);
            let a = model_a.predict(&run)?.total_s / 60.0;
            let b = model_b.predict(&run)?.total_s / 60.0;
            let m = probe::measured_execution_s(&arch, p, &cfg)?;
            t.row(vec![
                p.to_string(),
                format!("{a:.1}"),
                format!("{b:.1}"),
                format!("{:.1}", m / 60.0),
                format!("{:.2}x", base / m),
            ]);
        }
        print!("{}", t.render());

        // The paper's headline numbers for 3,840 threads.
        let run = RunConfig::paper_default(&arch.name, 3840);
        let b3840 = model_b.predict(&run)?.total_s / 60.0;
        println!(
            "at 3,840 threads the {} CNN trains in ~{b3840:.1} min by model (b) \
             (paper: {} min)\n",
            arch.name,
            match arch.name.as_str() {
                "small" => "4.6",
                "medium" => "14.5",
                _ => "18.0",
            }
        );
    }

    // Diminishing returns: Result 2's closing observation.
    let arch = ArchSpec::small();
    let (model_a, _) = both_models(&arch, ParamSource::Paper)?;
    let t240 = model_a.predict(&RunConfig::paper_default("small", 240))?.total_s;
    let t480 = model_a.predict(&RunConfig::paper_default("small", 480))?.total_s;
    println!(
        "doubling 240 -> 480 threads cuts small-CNN time by only {:.0}% \
         (not 50%): contention + CPI dominate (Result 2).",
        (1.0 - t480 / t240) * 100.0
    );
    Ok(())
}
