//! End-to-end driver (deliverable (b)/e2e): really train a CNN through the
//! full three-layer stack and log the loss curve.
//!
//! The path exercised: Pallas conv/pool kernels (L1) → JAX train step
//! (L2) → AOT HLO text (`make artifacts`) → Rust PJRT runtime →
//! coordinator leader loop (L3). Python is not involved at runtime.
//!
//! Dataset: real MNIST if `--mnist DIR` files exist, otherwise the
//! deterministic synthetic digit corpus (same shapes/label balance —
//! DESIGN.md §1).
//!
//! Run: `make artifacts && cargo run --release --example train_mnist`
//! (arguments: [arch] [epochs] [n_train], defaults: small 4 3072).
//! The run is recorded in EXPERIMENTS.md §e2e.

use micdl::coordinator::leader::{LeaderConfig, PjrtTrainer};
use micdl::coordinator::pool::{DataParallelTrainer, PoolConfig};
use micdl::config::ArchSpec;
use micdl::dataset;

fn main() -> micdl::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let arch = args.first().cloned().unwrap_or_else(|| "small".into());
    let epochs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let n_train: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(3072);

    let (train, test) = dataset::load_or_synth(None, n_train, 512, 1234);
    println!(
        "== end-to-end training: {arch} CNN, {} train / {} test images ({}) ==",
        train.len(),
        test.len(),
        train.source
    );

    // --- PJRT path (the AOT artifact) -----------------------------------
    let dir = std::path::PathBuf::from("artifacts");
    if dir.join("meta.json").exists() {
        println!("\n-- PJRT backend (Pallas/JAX AOT artifact) --");
        let cfg = LeaderConfig {
            arch: arch.clone(),
            epochs,
            eval_cap_batches: 8,
            seed: 42,
            verbose: true,
        };
        let mut trainer = PjrtTrainer::new(&dir, cfg)?;
        let report = trainer.train(&train, &test)?;
        println!("loss curve (epoch, mean batch loss):");
        for (e, l) in report.loss_curve() {
            println!("  {e:>3}  {l:.4}");
        }
        println!(
            "PJRT: {:.0} img/s, {} steps, final test accuracy {:.3}, converging={}",
            report.train_throughput,
            trainer.steps(),
            report.final_test_accuracy(),
            report.converging()
        );
        assert!(report.converging(), "loss curve must fall");
    } else {
        println!("(artifacts/ missing — run `make artifacts` for the PJRT path)");
    }

    // --- engine path (pure-Rust data-parallel pool) ----------------------
    println!("\n-- engine backend (data-parallel worker pool) --");
    let cfg = PoolConfig {
        workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        epochs,
        lr: 0.02,
        eval_cap: 512,
        seed: 42,
        verbose: true,
    };
    let mut trainer = DataParallelTrainer::new(ArchSpec::by_name(&arch)?, cfg)?;
    let report = trainer.train(&train, &test)?;
    println!(
        "engine: {:.0} img/s over {} workers, final test accuracy {:.3}, converging={}",
        report.train_throughput,
        trainer.cfg.workers,
        report.final_test_accuracy(),
        report.converging()
    );
    assert!(report.converging(), "loss curve must fall");
    Ok(())
}
