//! Quickstart: the three things micdl does, in ~40 lines of user code.
//!
//! 1. Describe a workload (architecture + run parameters).
//! 2. *Predict* its execution time on the Xeon Phi with the paper's two
//!    performance models.
//! 3. *Measure* it on the micsim simulator and compare (the Δ metric).
//!
//! Run: `cargo run --release --example quickstart`

use micdl::config::{ArchSpec, RunConfig};
use micdl::perfmodel::{both_models, delta_pct, ParamSource, PerfModel};
use micdl::simulator::{probe, SimConfig};

fn main() -> micdl::Result<()> {
    // 1. The paper's medium CNN, standard MNIST workload, 240 threads.
    let arch = ArchSpec::medium();
    let run = RunConfig::paper_default(&arch.name, 240);
    println!(
        "workload: {} CNN, i={}, it={}, ep={}, p={}",
        arch.name, run.train_images, run.test_images, run.epochs, run.threads
    );

    // 2. Predict with strategies (a) and (b).
    let (model_a, model_b) = both_models(&arch, ParamSource::Paper)?;
    let pred_a = model_a.predict(&run)?;
    let pred_b = model_b.predict(&run)?;
    println!(
        "strategy (a): {:.1} min   (prep {:.1}s, compute {:.1}s, T_mem {:.1}s)",
        pred_a.total_s / 60.0,
        pred_a.prep_s,
        pred_a.train_s + pred_a.test_s,
        pred_a.mem_s
    );
    println!("strategy (b): {:.1} min", pred_b.total_s / 60.0);

    // 3. "Measure" on the simulated Xeon Phi 7120P and compute Δ.
    let cfg = SimConfig::default();
    let measured = probe::measured_execution_s(&arch, run.threads, &cfg)?;
    println!("micsim measured: {:.1} min", measured / 60.0);
    println!(
        "Δa = {:.1}%   Δb = {:.1}%   (paper's averages: 14.76% / 7.48%)",
        delta_pct(measured, pred_a.total_s),
        delta_pct(measured, pred_b.total_s)
    );
    Ok(())
}
