//! Custom architectures: the config system beyond the paper's three CNNs.
//!
//! Defines a LeNet-5-flavoured stack from JSON, validates it, counts its
//! operations, predicts its training time with both models (parameters
//! re-measured from micsim — no paper table covers a custom net), and
//! "measures" it on the simulator.
//!
//! Run: `cargo run --release --example custom_arch`

use micdl::config::{ArchSpec, RunConfig};
use micdl::nn::opcount;
use micdl::perfmodel::{both_models, delta_pct, ParamSource, PerfModel};
use micdl::simulator::{probe, simulate_training, SimConfig};

const LENETISH: &str = r#"{
  "name": "lenetish",
  "layers": [
    {"type": "conv", "maps": 6, "kernel": 4},
    {"type": "pool", "window": 2},
    {"type": "conv", "maps": 16, "kernel": 4},
    {"type": "pool", "window": 2},
    {"type": "dense", "units": 120},
    {"type": "dense", "units": 84},
    {"type": "dense", "units": 10}
  ]
}"#;

fn main() -> micdl::Result<()> {
    let arch = ArchSpec::from_json(LENETISH)?;
    println!("custom architecture {:?} validated:", arch.name);
    for shape in arch.shapes()? {
        println!("  {:?}  neurons={} weights={}", shape.spec, shape.neurons, shape.weights);
    }

    let ops = opcount::count(&arch)?;
    println!(
        "\nops/image: fprop {} (conv {}, fc {}, pool {}), bprop {}",
        ops.fprop.total(),
        ops.fprop.convolution,
        ops.fprop.fully_connected,
        ops.fprop.max_pool,
        ops.bprop.total()
    );

    // Predict vs simulate on a reduced workload (10k images, 5 epochs).
    let run = RunConfig { train_images: 10_000, test_images: 2_000, epochs: 5, threads: 240 };
    let (model_a, model_b) = both_models(&arch, ParamSource::Simulator)?;
    let cfg = SimConfig::default();
    let a = model_a.predict(&run)?.total_s;
    let b = model_b.predict(&run)?.total_s;
    // Compare totals (model predictions include the prep term; on this
    // deliberately small workload prep is not negligible).
    let m = simulate_training(&arch, &run, &cfg)?.total_s;
    println!("\npredicted (a): {:.1}s   predicted (b): {:.1}s   micsim: {m:.1}s", a, b);
    println!("Δa = {:.1}%   Δb = {:.1}%", delta_pct(m, a), delta_pct(m, b));

    // Contention probe for the custom net (scaled by parameter footprint).
    println!("\ncontention probe (s/image):");
    for p in [15usize, 240, 960] {
        println!("  p={p:<5} {:.3e}", probe::contention_probe(&arch, p, &cfg)?);
    }
    Ok(())
}
