#!/usr/bin/env python3
"""One-time bootstrap for baselines/measured_smoke.json.

The canonical way to (re)generate the measured conformance baseline is
the binary itself:

    cd rust && cargo run --release -- \
        conformance --write-baseline ../baselines/measured_smoke.json

This script exists because the baseline was first seeded in an
environment without a Rust toolchain (the same situation that produced
generate_ci_smoke.py for the prediction baseline). It replicates,
operation for operation, micsim's chunked-fidelity measured path
(rust/src/simulator/{cost,memory,machine,workload}.rs) on top of the
closed-form model predictions replicated by generate_ci_smoke.py, then
aggregates the per-cell Δ = |measured − predicted| / predicted × 100
into per-(grid × architecture × strategy) Δ bands for the three paper
evaluation grids (Tables IX, X, XI).

It also seeds baselines/closed_loop_smoke.json (--write-closed-loop):
the Table IX grid under --params sim, replicating the calibration
subsystem's ComputedSource resolution (rust/src/calibration/source.rs —
strategy (b)'s per-image times, prep, and contention probed from the
cost model; strategy (a)'s computed op counts with per-direction
cycles-per-op *fitted* against those probed times, folded into the
Table V OperationFactor, and the Prep estimate back-derived from the
probed preparation time) against the same measured path. Canonical
regeneration is `repro conformance --write-closed-loop`.

Before writing anything it self-checks against every anchor the green
Rust test suite pins:

  * Table III per-image forward/backward/prep times (cost.rs tests,
    probe.rs measured_params_near_table3);
  * Table IV contention at p = 240 (memory.rs, probe.rs);
  * per-(arch × strategy) mean Δ < 25 % over the measured threads
    (experiments/table9.rs deltas_in_paper_band);
  * average Δ < 30 % (perfmodel/accuracy.rs average_delta_in_papers_
    ballpark) and per-point Δ < 30 % (experiments/figs567.rs);
  * strategy (b) beats (a) for the medium CNN, within 1 pp for large
    (table9.rs strategy_b_beats_a_for_medium_and_large);
  * measured time monotone decreasing over 1/15/60/240 threads, with a
    30–240× speedup at 240 (workload.rs tests), and the large CNN's
    measured 240-thread time below its 120-thread time (figs567.rs).

Band tolerances in the emitted file are ±max(1.0 pp, 2 % relative) on
the mean and ±max(2.0 pp, 2 % relative) on the max — far above
double-precision replication noise (≲1e-12 pp), far below any genuine
simulator or model change.
"""

import json
import os

from generate_ci_smoke import (
    ARCHS, CLOCK_HZ, CORES, EPOCHS, MACHINE, MEASURED_THREADS,
    TEST_IMAGES, THREADS_PER_CORE, TRAIN_IMAGES,
    CPI_LADDER, FPROP_OPS, BPROP_OPS, PREP_OPS, cpi,
    predict_a, predict_b, self_check as ci_smoke_self_check,
)

# ---------------------------------------------------------------------------
# SimConfig::default() (rust/src/simulator/mod.rs)
# ---------------------------------------------------------------------------

FWD_CYCLES_PER_OP = 31.0
BWD_CYCLES_PER_OP = 13.7
EXEC_FRACTION = 0.75
L2_ALPHA = 0.35
L2_RATIO_CAP = 3.0
RING_BETA = 0.15
PREP_IO_S = 12.4
PREP_CYCLES_PER_WEIGHT = 15.5
SERIAL_CYCLES_PER_IMAGE = 4.0
OVERSUB_OVERHEAD = 0.05

# MachineConfig::xeon_phi_7120p() (rust/src/config/machine.rs)
L2_BYTES = 512 * 1024
MEMORY_BW_BYTES = 352.0e9

# ---------------------------------------------------------------------------
# ArchSpec::shapes() results for the paper architectures
# (rust/src/config/arch.rs; 29×29 input, valid convolutions)
# ---------------------------------------------------------------------------

# Per-layer (neurons, weights) including the input layer, in stack order.
SHAPES = {
    "small": [
        (841, 0),        # input 29×29
        (3380, 85),      # conv 5×(4×4): 26×26 maps
        (845, 0),        # pool 2×2: 13×13
        (10, 8460),      # dense 10, fan-in 845
    ],
    "medium": [
        (841, 0),
        (13520, 340),    # conv 20×(4×4): 26×26
        (3380, 0),       # pool 2×2: 13×13
        (3240, 20040),   # conv 40×(5×5): 9×9
        (360, 0),        # pool 3×3: 3×3
        (150, 54150),    # dense 150, fan-in 360
        (10, 1510),      # dense 10, fan-in 150
    ],
    "large": [
        (841, 0),
        (13520, 340),    # conv 20×(4×4): 26×26
        (3380, 0),       # pool 2×2: 13×13
        (7260, 10860),   # conv 60×(3×3): 11×11
        (3600, 216100),  # conv 100×(6×6): 6×6
        (900, 0),        # pool 2×2: 3×3
        (150, 135150),   # dense 150, fan-in 900
        (10, 1510),      # dense 10, fan-in 150
    ],
}

# ContentionParams::for_arch (rust/src/simulator/memory.rs): floor at
# p=1 and Table IV slope through the origin at p=240, against the
# reference 352 GB/s bandwidth.
CONTENTION_FLOOR_S = {"small": 7.10e-6, "medium": 1.56e-4, "large": 8.83e-4}
CONTENTION_AT_240_S = {"small": 1.40e-2, "medium": 3.83e-2, "large": 1.38e-1}


def cost_model(arch):
    """CostModel::new under OpSource::Paper, operation for operation."""
    shapes = SHAPES[arch]
    param_bytes = 0.0
    for _, w in shapes:
        param_bytes += float(w) * 4.0
    neuron_bytes = sorted((float(n) * 4.0 for n, _ in shapes), reverse=True)
    acts = neuron_bytes[0] + neuron_bytes[1]
    return {
        "fwd_cycles": FPROP_OPS[arch] * FWD_CYCLES_PER_OP,
        "bwd_cycles": BPROP_OPS[arch] * BWD_CYCLES_PER_OP,
        "working_set_bytes": param_bytes + acts,
        "contention_floor_s": CONTENTION_FLOOR_S[arch],
        "contention_traffic_bytes": CONTENTION_AT_240_S[arch] * MEMORY_BW_BYTES / 240.0,
        "param_bytes": param_bytes,
        "total_weights": float(sum(w for _, w in shapes)),
    }


# ---------------------------------------------------------------------------
# PhiMachine placement (rust/src/simulator/machine.rs)
# ---------------------------------------------------------------------------

def sw_threads_on_core(p, t):
    core = t % CORES
    return (p + CORES - 1 - core) // CORES


def occupancy_of(p, t):
    return min(sw_threads_on_core(p, t), THREADS_PER_CORE)


def oversub_of(p, t):
    sw = float(sw_threads_on_core(p, t))
    hw = float(occupancy_of(p, t))
    return max(sw / hw, 1.0)


def machine_cpi(occ):
    """MachineConfig::cpi (1-based ladder, saturating)."""
    if occ == 0:
        return CPI_LADDER[0]
    return CPI_LADDER[min(occ, len(CPI_LADDER)) - 1]


def contention_s(cm, p):
    """ContentionParams::contention_s."""
    queue = cm["contention_traffic_bytes"] * float(max(p - 1, 0)) / MEMORY_BW_BYTES
    return cm["contention_floor_s"] + queue


def l2_pressure(ws_bytes, occ):
    excess = ws_bytes * float(max(occ - 1, 0)) / float(L2_BYTES)
    return 1.0 + L2_ALPHA * min(excess, L2_RATIO_CAP)


def ring_factor(active):
    return 1.0 + RING_BETA * (float(max(active - 1, 0)) / float(CORES - 1))


def image_s(cm, p, t, cycles, updates_weights):
    """CostModel::image_s, operation for operation."""
    occ = occupancy_of(p, t)
    cpi = machine_cpi(occ)
    oversub = oversub_of(p, t)
    exec_ = cycles * EXEC_FRACTION * cpi
    active = min(p, CORES)
    mem = cycles * (1.0 - EXEC_FRACTION) * l2_pressure(cm["working_set_bytes"], occ) \
        * ring_factor(active)
    switch_penalty = 1.0 + OVERSUB_OVERHEAD * (oversub - 1.0)
    s = (exec_ + mem) * oversub * switch_penalty / CLOCK_HZ
    if updates_weights:
        s += contention_s(cm, p)
    return s


def fwd_image_s(cm, p, t):
    return image_s(cm, p, t, cm["fwd_cycles"], False)


def train_image_s(cm, p, t):
    return image_s(cm, p, t, cm["fwd_cycles"] + cm["bwd_cycles"], True)


def chunk_of(total, p, t):
    base = total // p
    extra = total % p
    return base + 1 if t < extra else base


def prep_s(cm, instances):
    return PREP_IO_S + float(instances) * cm["total_weights"] \
        * PREP_CYCLES_PER_WEIGHT / CLOCK_HZ


def epoch_serial_s(cm, i, it):
    return (float(i) * SERIAL_CYCLES_PER_IMAGE + float(it) * 2.0 + 10.0) / CLOCK_HZ


def measured_execution_s(arch, i, it, ep, p):
    """simulate_chunked (rust/src/simulator/workload.rs): execution_s of
    the Fig. 4 workload — total minus prep."""
    cm = cost_model(arch)
    prep = prep_s(cm, p)
    serial_epoch = epoch_serial_s(cm, i, it)
    train_max = val_max = test_max = 0.0
    window = min(p, CORES)
    candidates = [0] + list(range(p - window, p))
    for t in candidates:
        train_chunk = float(chunk_of(i, p, t))
        test_chunk = float(chunk_of(it, p, t))
        fwd = fwd_image_s(cm, p, t)
        train_max = max(train_max, train_chunk * train_image_s(cm, p, t))
        val_max = max(val_max, train_chunk * fwd)
        test_max = max(test_max, test_chunk * fwd)
    ep_f = float(ep)
    phases = (prep, train_max * ep_f, val_max * ep_f, test_max * ep_f,
              serial_epoch * ep_f)
    total = phases[0] + phases[1] + phases[2] + phases[3] + phases[4]
    return total - prep


def delta_pct(measured, predicted):
    return abs(measured - predicted) / predicted * 100.0


# ---------------------------------------------------------------------------
# The three conformance grids (sweep::conformance::paper_grids)
# ---------------------------------------------------------------------------

TABLE10_THREADS = [480, 960, 1920, 3840]
TABLE11_IMAGES = [(60_000, 10_000), (120_000, 20_000), (240_000, 40_000)]
TABLE11_EPOCHS = [70, 140, 280]
TABLE11_THREADS = [240, 480]

# Paper Table IX Δ per architecture, columns (a, b) — report/paper.rs
# ACCURACY_DELTA_PCT. The headline claim is the per-strategy mean.
PAPER_DELTA_PCT = {
    "small": (14.57, 16.35),
    "medium": (14.76, 7.48),
    "large": (15.36, 10.22),
}

# Band tolerances, percentage points: floor for the Table IX scale
# (Δ ≈ 5–25 %), 2 % relative for the extrapolation grids where Δ runs to
# hundreds of percent and absolute points would over-tighten.
MEAN_TOL_PP_FLOOR = 1.0
MAX_TOL_PP_FLOOR = 2.0
TOL_REL = 0.02
CLAIM_HEADROOM_PP = 3.0


def mean_tol_pp(mean):
    return max(MEAN_TOL_PP_FLOOR, TOL_REL * mean)


def max_tol_pp(mx):
    return max(MAX_TOL_PP_FLOOR, TOL_REL * mx)


def grid_defs():
    """(id, spec-json, scenario list) per grid, scenarios in
    GridSpec::enumerate order (arch → machine → images → epochs →
    threads → strategy)."""
    grids = []

    def enumerate_grid(archs, images, epochs, threads, strategies):
        out = []
        for arch in archs:
            eps = epochs if epochs else [EPOCHS[arch]]
            for (i, it) in images:
                for ep in eps:
                    for p in threads:
                        for s in strategies:
                            out.append((arch, i, it, ep, p, s))
        return out

    def spec(archs, images, epochs, threads, strategies):
        doc = {
            "archs": archs,
            "threads": threads,
            "images": [list(pair) for pair in images],
        }
        if epochs:
            doc["epochs"] = epochs
        doc["strategies"] = strategies
        doc["params"] = "paper"
        doc["measure"] = True
        return doc

    # Table IX: the measured evaluation domain (42 cells).
    grids.append((
        "table9",
        spec(ARCHS, [(TRAIN_IMAGES, TEST_IMAGES)], [], MEASURED_THREADS,
             ["a", "b"]),
        enumerate_grid(ARCHS, [(TRAIN_IMAGES, TEST_IMAGES)], [],
                       MEASURED_THREADS, ["a", "b"]),
    ))
    # Table X: extrapolation beyond the hardware thread count (24 cells).
    grids.append((
        "table10",
        spec(ARCHS, [(TRAIN_IMAGES, TEST_IMAGES)], [], TABLE10_THREADS,
             ["a", "b"]),
        enumerate_grid(ARCHS, [(TRAIN_IMAGES, TEST_IMAGES)], [],
                       TABLE10_THREADS, ["a", "b"]),
    ))
    # Table XI: workload scaling, small CNN, strategy (a) (18 cells).
    grids.append((
        "table11",
        spec(["small"], TABLE11_IMAGES, TABLE11_EPOCHS, TABLE11_THREADS,
             ["a"]),
        enumerate_grid(["small"], TABLE11_IMAGES, TABLE11_EPOCHS,
                       TABLE11_THREADS, ["a"]),
    ))
    return grids


def evaluate(scenarios):
    """Per-scenario (measured, predicted, Δ)."""
    rows = []
    for (arch, i, it, ep, p, s) in scenarios:
        predicted = (predict_a if s == "a" else predict_b)(arch, i, it, ep, p)
        measured = measured_execution_s(arch, i, it, ep, p)
        rows.append((arch, i, it, ep, p, s, measured, predicted,
                     delta_pct(measured, predicted)))
    return rows


def bands_of(rows):
    """Per-(arch × strategy) mean/max Δ, groups in axis order, Δ folded
    in enumeration order (SweepResults::accuracy)."""
    order, groups = [], {}
    for row in rows:
        key = (row[0], row[5])
        if key not in groups:
            order.append(key)
            groups[key] = []
        groups[key].append(row)
    bands = []
    for (arch, strategy) in order:
        cells = groups[(arch, strategy)]
        total = 0.0
        mx, mx_at = -1.0, 0
        for row in cells:
            d, p = row[8], row[4]
            total += d
            if d > mx:
                mx, mx_at = d, p
        mean = total / float(len(cells))
        bands.append({
            "arch": arch,
            "strategy": strategy,
            "points": len(cells),
            "mean_delta_pct": mean,
            "max_delta_pct": mx,
            "max_at_threads": mx_at,
            "mean_tol_pp": mean_tol_pp(mean),
            "max_tol_pp": max_tol_pp(mx),
        })
    return bands


def overall_mean(rows, strategy):
    deltas = [r[8] for r in rows if r[5] == strategy]
    return sum(deltas) / float(len(deltas))


def self_check(results):
    """Pin the micsim replication against the anchors the green Rust
    test suite asserts."""
    ci_smoke_self_check()  # the prediction side first
    # Table III anchors (cost.rs / probe.rs): fwd/bwd per image within
    # 12 %, prep within 8 %.
    t3 = {
        "small": (1.45e-3, 5.3e-3, 12.56),
        "medium": (12.55e-3, 69.73e-3, 12.7),
        "large": (148.88e-3, 859.19e-3, 13.5),
    }
    for arch, (f_want, b_want, prep_want) in t3.items():
        cm = cost_model(arch)
        fwd = fwd_image_s(cm, 1, 0)
        bwd = train_image_s(cm, 1, 0) - fwd
        prep = prep_s(cm, 240)
        assert abs(fwd - f_want) / f_want < 0.12, (arch, "fwd", fwd)
        assert abs(bwd - b_want) / b_want < 0.12, (arch, "bwd", bwd)
        assert abs(prep - prep_want) / prep_want < 0.08, (arch, "prep", prep)
    # Table IV anchor (memory.rs): contention at 240 within 2 %.
    for arch, want in CONTENTION_AT_240_S.items():
        got = contention_s(cost_model(arch), 240)
        assert abs(got - want) / want < 0.02, (arch, got)
    # Measured-time shape (workload.rs): monotone in threads, sublinear
    # speedup in (30, 240) at one epoch.
    ts = {p: measured_execution_s("small", 60_000, 10_000, 1, p)
          for p in (1, 15, 60, 240)}
    assert ts[1] > ts[15] > ts[60] > ts[240], ts
    assert 30.0 < ts[1] / ts[240] < 240.0, ts[1] / ts[240]
    # figs567.rs: the large CNN's measured time keeps dropping 120→240.
    m120 = measured_execution_s("large", 60_000, 10_000, 15, 120)
    m240 = measured_execution_s("large", 60_000, 10_000, 15, 240)
    assert m240 < m120, (m120, m240)
    # Δ anchors over the Table IX grid (table9.rs / figs567.rs /
    # accuracy.rs): per-point < 30, per-group mean < 25, (b) beats (a)
    # for medium (strictly) and large (within 1 pp).
    rows9 = results["table9"]
    assert all(r[8] < 30.0 for r in rows9), max(r[8] for r in rows9)
    means = {(b["arch"], b["strategy"]): b["mean_delta_pct"]
             for b in bands_of(rows9)}
    assert all(m < 25.0 for m in means.values()), means
    assert means[("medium", "b")] < means[("medium", "a")], means
    assert means[("large", "b")] < means[("large", "a")] + 1.0, means


def build():
    results = {}
    grids_out = []
    for (gid, spec, scenarios) in grid_defs():
        rows = evaluate(scenarios)
        results[gid] = rows
        grids_out.append({"id": gid, "spec": spec, "bands": bands_of(rows)})
    self_check(results)
    claims = []
    for idx, strategy in enumerate(("a", "b")):
        paper = sum(v[idx] for v in PAPER_DELTA_PCT.values()) / 3.0
        observed = overall_mean(results["table9"], strategy)
        claims.append({
            "strategy": strategy,
            "grid": "table9",
            "paper_mean_pct": paper,
            "ceiling_pct": max(paper, observed + CLAIM_HEADROOM_PP),
        })
    return {
        "kind": "micdl-conformance-baseline",
        "version": 1,
        "claims": claims,
        "grids": grids_out,
    }, results


# ---------------------------------------------------------------------------
# Closed-loop grid (table9 under --params sim): model parameters probed
# from the same simulator that produces the measurements
# (GridSpec::table9_closed_loop / sweep::conformance::closed_loop_grids)
# ---------------------------------------------------------------------------

CLOSED_LOOP_GRID = "table9_closed_loop"

# The paper layer stacks (rust/src/config/arch.rs), as
# (kind, arg1, arg2) over the 29x29 input: conv(maps, kernel),
# pool(window), dense(units).
LAYER_STACKS = {
    "small": [("conv", 5, 4), ("pool", 2, 0), ("dense", 10, 0)],
    "medium": [("conv", 20, 4), ("pool", 2, 0), ("conv", 40, 5), ("pool", 3, 0),
               ("dense", 150, 0), ("dense", 10, 0)],
    "large": [("conv", 20, 4), ("pool", 2, 0), ("conv", 60, 3), ("conv", 100, 6),
              ("pool", 2, 0), ("dense", 150, 0), ("dense", 10, 0)],
}

# opcount.rs counting constants.
ACT_FWD_OPS = 4
ACT_BWD_OPS = 3
WEIGHT_UPDATE_OPS = 3


def computed_op_counts(arch):
    """opcount::count (OpSource::Computed), operation for operation:
    first-principles fwd/bwd totals from the layer geometry."""
    hw, maps, prev_neurons = 29, 1, 29 * 29
    fwd_total, bwd_total = 0, 0
    for (kind, a, b) in LAYER_STACKS[arch]:
        if kind == "conv":
            out_hw = hw - b + 1
            neurons = a * out_hw * out_hw
            fan_in = maps * b * b
            weights = a * (fan_in + 1)
            fwd_total += neurons * (2 * fan_in + ACT_FWD_OPS)
            bwd_total += neurons * (2 * fan_in + ACT_BWD_OPS) \
                + weights * WEIGHT_UPDATE_OPS
            hw, maps, prev_neurons = out_hw, a, neurons
        elif kind == "pool":
            out_hw = hw // a
            neurons = maps * out_hw * out_hw
            fwd_total += neurons * (a * a + 1)
            bwd_total += neurons * 2
            hw, prev_neurons = out_hw, neurons
        else:  # dense
            fan_in = prev_neurons
            weights = a * (fan_in + 1)
            fwd_total += a * (2 * fan_in + ACT_FWD_OPS)
            bwd_total += a * (2 * fan_in + ACT_BWD_OPS) \
                + weights * WEIGHT_UPDATE_OPS
            prev_neurons = a
    return float(fwd_total), float(bwd_total)


def calibrated_a_params(arch):
    """calibration::ComputedSource::resolve, operation for operation:
    per-direction cycles-per-op fitted so the *computed* op counts
    reproduce the probed per-image times, folded into the single Table V
    OperationFactor with the (FProp + BProp + FProp) term mix, and the
    Prep estimate back-derived from the probed preparation time.
    Returns (fprop_ops, bprop_ops, prep_ops, operation_factor)."""
    f, b = computed_op_counts(arch)
    cm = cost_model(arch)
    tf = fwd_image_s(cm, 1, 0)
    tb = train_image_s(cm, 1, 0) - tf
    fwd_cycles_fit = tf * CLOCK_HZ / f
    bwd_cycles_fit = tb * CLOCK_HZ / b
    of = (2.0 * f * fwd_cycles_fit + b * bwd_cycles_fit) / (2.0 * f + b)
    prep_ops = prep_s(cm, 240) * CLOCK_HZ / of
    return f, b, prep_ops, of


def sim_contention_s(cm, p):
    """probe::contention_probe_with: 16 deterministic rounds averaged
    (the loop is replicated so IEEE rounding matches bit for bit)."""
    total = 0.0
    for _round in range(16):
        total += contention_s(cm, p)
    return total / 16.0


def t_mem_sim_s(cm, ep, i, p):
    return sim_contention_s(cm, p) * float(ep) * float(i) / float(p)


def predict_a_sim(arch, i, it, ep, p):
    """StrategyA::with_sim(Simulator).predict: the calibrated
    ComputedSource parameterization (computed op counts, fitted
    OperationFactor, back-derived Prep, probe-derived contention)."""
    s = CLOCK_HZ
    f, b, prep_ops, of = calibrated_a_params(arch)
    c = cpi(p)
    chunk_i = float(i) / float(p)
    chunk_it = float(it) / float(p)
    cm = cost_model(arch)
    prep_s_ = (prep_ops * of + 4.0 * i + 2.0 * it + 10.0 * ep) / s
    train_s = (f + b + f) * chunk_i * ep * of * c / s
    test_s = f * chunk_it * ep * of * c / s
    mem_s = t_mem_sim_s(cm, ep, i, p)
    return prep_s_ + train_s + test_s + mem_s


def predict_b_sim(arch, i, it, ep, p):
    """StrategyB::with_sim(Simulator).predict: per-image times probed
    from micsim at one thread (probe::measure_image_times)."""
    c = cpi(p)
    chunk_i = float(i) / float(p)
    chunk_it = float(it) / float(p)
    cm = cost_model(arch)
    tf = fwd_image_s(cm, 1, 0)
    tb = train_image_s(cm, 1, 0) - tf
    tprep = prep_s(cm, 240)
    train_s = (tf + tb + tf) * chunk_i * ep * c
    test_s = tf * chunk_it * ep * c
    mem_s = t_mem_sim_s(cm, ep, i, p)
    return tprep + train_s + test_s + mem_s


def closed_loop_grid_def():
    """(id, spec-json, scenarios) for the closed-loop grid: the Table IX
    domain with params = sim."""
    spec = {
        "archs": ARCHS,
        "threads": MEASURED_THREADS,
        "images": [[TRAIN_IMAGES, TEST_IMAGES]],
        "strategies": ["a", "b"],
        "params": "sim",
        "measure": True,
    }
    scenarios = []
    for arch in ARCHS:
        for p in MEASURED_THREADS:
            for s in ("a", "b"):
                scenarios.append((arch, TRAIN_IMAGES, TEST_IMAGES,
                                  EPOCHS[arch], p, s))
    return (CLOSED_LOOP_GRID, spec, scenarios)


def evaluate_closed_loop(scenarios):
    rows = []
    for (arch, i, it, ep, p, s) in scenarios:
        predicted = (predict_a_sim if s == "a" else predict_b_sim)(
            arch, i, it, ep, p)
        measured = measured_execution_s(arch, i, it, ep, p)
        rows.append((arch, i, it, ep, p, s, measured, predicted,
                     delta_pct(measured, predicted)))
    return rows


def self_check_closed_loop(rows, paper_rows):
    """Anchors for the closed-loop replication."""
    # Computed op counts pin the documented counting scheme exactly
    # (opcount.rs tests::small_exact_values_pinned for small; the other
    # totals are regression pins for this replication).
    assert computed_op_counts("small") == (142_845.0, 162_555.0)
    assert computed_op_counts("medium") == (3_871_820.0, 4_070_000.0)
    assert computed_op_counts("large") == (18_990_800.0, 20_045_300.0)
    # Probed strategy-(b) params stay near Table III (probe.rs
    # measured_params_near_table3: within 12 %).
    for arch, (f_want, b_want, _) in {
        "small": (1.45e-3, 5.3e-3, None),
        "medium": (12.55e-3, 69.73e-3, None),
        "large": (148.88e-3, 859.19e-3, None),
    }.items():
        cm = cost_model(arch)
        tf = fwd_image_s(cm, 1, 0)
        tb = train_image_s(cm, 1, 0) - tf
        assert abs(tf - f_want) / f_want < 0.12, (arch, tf)
        assert abs(tb - b_want) / b_want < 0.12, (arch, tb)
    # The ComputedSource fit round-trips: computed counts × fitted
    # OperationFactor reproduce the probed training-image time, and the
    # Prep term lands on the probed preparation time
    # (calibration/source.rs tests::computed_source_fit_reproduces_
    # probed_times).
    for arch in ARCHS:
        f, b, prep_ops, of = calibrated_a_params(arch)
        cm = cost_model(arch)
        tf = fwd_image_s(cm, 1, 0)
        tb = train_image_s(cm, 1, 0) - tf
        probed = 2.0 * tf + tb
        fitted = (2.0 * f + b) * of / CLOCK_HZ
        assert abs(fitted - probed) / probed < 1e-12, (arch, fitted, probed)
        prep_fit = prep_ops * of / CLOCK_HZ
        assert abs(prep_fit - prep_s(cm, 240)) / prep_s(cm, 240) < 1e-12, arch
    # Every closed-loop cell is finite and nonnegative.
    assert all(r[8] >= 0.0 and r[8] == r[8] for r in rows)
    means = {(b["arch"], b["strategy"]): b["mean_delta_pct"]
             for b in bands_of(rows)}
    # Strategy (b) fully closes the loop — its parameters (per-image
    # times, prep, contention) are probed from the measuring simulator —
    # so the residual Δ is purely structural (fractional vs ceiling
    # division, L2/ring memory effects): every group stays under 10 %,
    # and the overall mean beats the open-loop (paper-parameter) run.
    for arch in ARCHS:
        assert means[(arch, "b")] < 10.0, (arch, means)
    closed_b = overall_mean(rows, "b")
    open_b = overall_mean(paper_rows, "b")
    assert closed_b < open_b, (closed_b, open_b)
    # Strategy (a) is now fully closed too (calibration::ComputedSource):
    # the fitted cycles absorb the computed-vs-paper op-count gap that
    # used to pin the medium CNN at ~58 %, leaving only the Table V
    # single-OperationFactor structure (the test-term distortion) on top
    # of (b)'s structural residual. Every (a) group stays under 10 %,
    # the medium band tightens to the structural few percent, and the
    # closed-loop (a) mean beats the open-loop (a) run.
    for arch in ARCHS:
        assert means[(arch, "a")] < 10.0, (arch, means)
    assert means[("medium", "a")] < 5.0, means
    closed_a = overall_mean(rows, "a")
    open_a = overall_mean(paper_rows, "a")
    assert closed_a < open_a, (closed_a, open_a)


def build_closed_loop(paper_rows):
    gid, spec, scenarios = closed_loop_grid_def()
    rows = evaluate_closed_loop(scenarios)
    self_check_closed_loop(rows, paper_rows)
    claims = []
    for idx, strategy in enumerate(("a", "b")):
        paper = sum(v[idx] for v in PAPER_DELTA_PCT.values()) / 3.0
        observed = overall_mean(rows, strategy)
        claims.append({
            "strategy": strategy,
            "grid": gid,
            "paper_mean_pct": paper,
            "ceiling_pct": max(paper, observed + CLAIM_HEADROOM_PP),
        })
    doc = {
        "kind": "micdl-conformance-baseline",
        "version": 1,
        "claims": claims,
        "grids": [{"id": gid, "spec": spec, "bands": bands_of(rows)}],
    }
    return doc, rows


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--write", action="store_true",
                    help="overwrite baselines/measured_smoke.json "
                         "(default: self-check + print the bands only)")
    ap.add_argument("--write-closed-loop", action="store_true",
                    help="overwrite baselines/closed_loop_smoke.json "
                         "(the table9 --params sim grid)")
    args = ap.parse_args()
    doc, results = build()
    for grid in doc["grids"]:
        print(f"{grid['id']}: {len(results[grid['id']])} cells")
        for band in grid["bands"]:
            print(f"  {band['arch']}/{band['strategy']}: "
                  f"mean Δ {band['mean_delta_pct']:.3f}%  "
                  f"max Δ {band['max_delta_pct']:.3f}% "
                  f"@ p={band['max_at_threads']} "
                  f"({band['points']} points)")
    for claim in doc["claims"]:
        print(f"claim {claim['strategy']}: paper {claim['paper_mean_pct']:.2f}% "
              f"ceiling {claim['ceiling_pct']:.2f}%")
    cl_doc, cl_rows = build_closed_loop(results["table9"])
    print(f"{CLOSED_LOOP_GRID}: {len(cl_rows)} cells")
    for band in cl_doc["grids"][0]["bands"]:
        print(f"  {band['arch']}/{band['strategy']}: "
              f"mean Δ {band['mean_delta_pct']:.3f}%  "
              f"max Δ {band['max_delta_pct']:.3f}% "
              f"@ p={band['max_at_threads']} "
              f"({band['points']} points)")
    for claim in cl_doc["claims"]:
        print(f"closed-loop claim {claim['strategy']}: "
              f"paper {claim['paper_mean_pct']:.2f}% "
              f"ceiling {claim['ceiling_pct']:.2f}%")
    here = os.path.dirname(os.path.abspath(__file__))
    wrote = False
    if args.write:
        out = os.path.join(here, "measured_smoke.json")
        with open(out, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"wrote {out}")
        wrote = True
    if args.write_closed_loop:
        out = os.path.join(here, "closed_loop_smoke.json")
        with open(out, "w") as f:
            json.dump(cl_doc, f, indent=1)
            f.write("\n")
        print(f"wrote {out}")
        wrote = True
    if not wrote:
        print("self-check OK; pass --write and/or --write-closed-loop "
              "to overwrite the baseline file(s)")


if __name__ == "__main__":
    main()
