#!/usr/bin/env python3
"""One-time bootstrap for baselines/ci_smoke.json.

The canonical way to (re)generate a sweep baseline is the binary itself:

    cd rust && cargo run --release -- \
        sweep --serial --write-baseline ../baselines/ci_smoke.json

This script exists because the baseline was first seeded in an
environment without a Rust toolchain. It replicates, operation for
operation, the closed-form strategy (a)/(b) predictions of
rust/src/perfmodel/{strategy_a,strategy_b}.rs under ParamSource::Paper
for the default sweep grid (3 paper architectures x the 7 measured
thread counts x both strategies, prediction-only), and self-checks the
results against the paper anchors the Rust tests pin (Table X/XI cells,
the selfcheck anchor small@480). Values agree with the Rust sweep to
double-precision rounding, far inside the compare tolerance (1e-6).
"""

import json
import math
import os

CLOCK_HZ = 1.238e9
OPERATION_FACTOR = 15.0
MACHINE = "Intel Xeon Phi 7120P (KNC)"
CORES, THREADS_PER_CORE = 61, 4
CPI_LADDER = [1.0, 1.0, 1.5, 2.0]

ARCHS = ["small", "medium", "large"]
EPOCHS = {"small": 70, "medium": 70, "large": 15}
TRAIN_IMAGES, TEST_IMAGES = 60_000, 10_000
MEASURED_THREADS = [1, 15, 30, 60, 120, 180, 240]

# Tables VII/VIII totals (operations per image).
FPROP_OPS = {"small": 58_000.0, "medium": 559_000.0, "large": 5_349_000.0}
BPROP_OPS = {"small": 524_000.0, "medium": 6_119_000.0, "large": 73_178_000.0}
# MODEL_PREP_OPS (report/paper.rs): the Prep counts the paper's published
# predictions embed (medium reproduces Table X only with 1e9).
PREP_OPS = {"small": 1e9, "medium": 1e9, "large": 1e11}
# Table III measured parameters (strategy b).
T_FPROP_S = {"small": 1.45e-3, "medium": 12.55e-3, "large": 148.88e-3}
T_BPROP_S = {"small": 5.3e-3, "medium": 69.73e-3, "large": 859.19e-3}
T_PREP_S = {"small": 12.56, "medium": 12.7, "large": 13.5}

# Table IV MemoryContention(p), seconds (report/paper.rs CONTENTION_S).
CONTENTION_THREADS = [1, 15, 30, 60, 120, 180, 240, 480, 960, 1920, 3840]
CONTENTION_S = {
    "small": [7.10e-6, 6.40e-4, 1.36e-3, 3.07e-3, 6.76e-3, 9.95e-3, 1.40e-2,
              2.78e-2, 5.60e-2, 1.12e-1, 2.25e-1],
    "medium": [1.56e-4, 2.00e-3, 3.97e-3, 8.03e-3, 1.65e-2, 2.50e-2, 3.83e-2,
               7.31e-2, 1.47e-1, 2.95e-1, 5.91e-1],
    "large": [8.83e-4, 8.75e-3, 1.67e-2, 3.22e-2, 6.74e-2, 1.00e-1, 1.38e-1,
              2.73e-1, 5.46e-1, 1.09, 2.19],
}


def cpi(p):
    """MachineConfig::cpi(occupancy(p)) for the 7120P."""
    occ = min(-(-p // CORES), THREADS_PER_CORE)
    return CPI_LADDER[min(occ, len(CPI_LADDER)) - 1]


def contention(arch, p):
    return CONTENTION_S[arch][CONTENTION_THREADS.index(p)]


def t_mem_s(arch, ep, i, p):
    return contention(arch, p) * float(ep) * float(i) / float(p)


def predict_a(arch, i, it, ep, p):
    """strategy_a.rs::predict, operation for operation."""
    s = CLOCK_HZ
    of = OPERATION_FACTOR
    c = cpi(p)
    chunk_i = float(i) / float(p)
    chunk_it = float(it) / float(p)
    f, b = FPROP_OPS[arch], BPROP_OPS[arch]
    prep_s = (PREP_OPS[arch] * of + 4.0 * i + 2.0 * it + 10.0 * ep) / s
    train_s = (f + b + f) * chunk_i * ep * of * c / s
    test_s = f * chunk_it * ep * of * c / s
    mem_s = t_mem_s(arch, ep, i, p)
    return prep_s + train_s + test_s + mem_s


def predict_b(arch, i, it, ep, p):
    """strategy_b.rs::predict, operation for operation."""
    c = cpi(p)
    chunk_i = float(i) / float(p)
    chunk_it = float(it) / float(p)
    tf, tb = T_FPROP_S[arch], T_BPROP_S[arch]
    prep_s = T_PREP_S[arch]
    train_s = (tf + tb + tf) * chunk_i * ep * c
    test_s = tf * chunk_it * ep * c
    mem_s = t_mem_s(arch, ep, i, p)
    return prep_s + train_s + test_s + mem_s


def self_check():
    """Pin the replication against the paper anchors the Rust tests use."""
    # Selfcheck anchor (main.rs): small @ 480 threads.
    assert abs(predict_a("small", 60_000, 10_000, 70, 480) / 60.0 - 6.6) < 0.3
    assert abs(predict_b("small", 60_000, 10_000, 70, 480) / 60.0 - 6.7) < 0.3
    # Table X, all six architecture/strategy columns at 480..3840.
    table10 = {
        480: [6.6, 6.7, 36.8, 39.1, 92.9, 82.6],
        960: [5.4, 5.5, 23.9, 25.1, 60.8, 45.7],
        1920: [4.9, 4.9, 17.4, 18.0, 44.8, 27.2],
        3840: [4.6, 4.6, 14.2, 14.5, 36.8, 18.0],
    }
    for p, cells in table10.items():
        for col, arch in enumerate(ARCHS):
            ep = EPOCHS[arch]
            got_a = predict_a(arch, TRAIN_IMAGES, TEST_IMAGES, ep, p) / 60.0
            got_b = predict_b(arch, TRAIN_IMAGES, TEST_IMAGES, ep, p) / 60.0
            assert abs(got_a - cells[col * 2]) / cells[col * 2] < 0.02, (arch, p)
            assert abs(got_b - cells[col * 2 + 1]) / cells[col * 2 + 1] < 0.015, (arch, p)
    # Table XI corner: small, 240 threads, 70 epochs -> 8.9 minutes.
    assert abs(predict_a("small", 60_000, 10_000, 70, 240) / 60.0 - 8.9) < 0.3


def build():
    cells = []
    # Enumeration order: arch -> machine -> images -> epochs -> threads
    # -> strategy (GridSpec::enumerate).
    for arch in ARCHS:
        ep = EPOCHS[arch]
        for p in MEASURED_THREADS:
            for strategy, predict in (("a", predict_a), ("b", predict_b)):
                cells.append({
                    "arch": arch,
                    "machine": MACHINE,
                    "threads": p,
                    "train_images": TRAIN_IMAGES,
                    "test_images": TEST_IMAGES,
                    "epochs": ep,
                    "strategy": strategy,
                    "total_s": predict(arch, TRAIN_IMAGES, TEST_IMAGES, ep, p),
                })
    return {
        "kind": "micdl-sweep-baseline",
        "version": 1,
        # GridSpec::to_spec_json of the default grid.
        "grid": {
            "archs": ARCHS,
            "threads": MEASURED_THREADS,
            "images": [[TRAIN_IMAGES, TEST_IMAGES]],
            "strategies": ["a", "b"],
            "params": "paper",
            "measure": False,
        },
        "cells": cells,
    }


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--write", action="store_true",
                    help="overwrite baselines/ci_smoke.json (default: "
                         "self-check only, so a stray invocation cannot "
                         "clobber a canonically regenerated baseline)")
    args = ap.parse_args()
    self_check()
    doc = build()
    total_min = sum(c["total_s"] for c in doc["cells"]) / 60.0
    if not args.write:
        print(f"self-check OK: {len(doc['cells'])} cells "
              f"(sum {total_min:.1f} predicted minutes); "
              f"pass --write to overwrite ci_smoke.json")
        return
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "ci_smoke.json")
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"wrote {out}: {len(doc['cells'])} cells "
          f"(sum {total_min:.1f} predicted minutes)")


if __name__ == "__main__":
    main()
