#!/usr/bin/env python3
"""One-time bootstrap for baselines/residual_smoke.json.

The canonical way to (re)generate the residual conformance baseline is
the binary itself:

    cd rust && cargo run --release -- \
        conformance --write-residual ../baselines/residual_smoke.json

This script exists because the baseline was first seeded in an
environment without a Rust toolchain (the same situation that produced
generate_ci_smoke.py and generate_measured_smoke.py). It replicates,
operation for operation, the strategy (c) residual regressor
(rust/src/calibration/residual.rs + rust/src/perfmodel/strategy_c.rs):

  * the seeded training grid — the Table IV thread ladder crossed with
    four deterministic workload variants (the paper workload, its
    2x/4x Table XI scalings, and one XorShift64-jittered variant seeded
    from SimConfig::seed ^ fnv1a(arch));
  * the residual target z = ln(measured execution / strategy-(b)
    predicted total) over that grid, with the measured side replicated
    by generate_measured_smoke.py's micsim port;
  * the ridge fit (X^T X + lambda I) w = X^T z solved by Gaussian
    elimination with partial pivoting, strictly in training-grid order;
  * the (c) prediction: strategy (b)'s total scaled by exp(w . x).

Grids: the Tables IX-XI domains with strategies (b, c) so every band
file pins the ordering claim — (c)'s mean Δ strictly below (b)'s on the
same cells. Self-checks assert that ordering with margin, the k-fold
held-out gate the Rust tests pin (held-out mean Δ of (c) within
tolerance of in-sample and below (b)'s band), and determinism of the
seeded grid. Band tolerances are the measured-smoke ones: ±max(1 pp,
2 % relative) on the mean, ±max(2 pp, 2 % relative) on the max — far
above the Python-vs-Rust libm replication noise, far below a genuine
model change.
"""

import json
import math
import os

from generate_ci_smoke import (
    ARCHS, CONTENTION_THREADS as LADDER_THREADS,  # Table IV thread ladder
    EPOCHS, MEASURED_THREADS, TEST_IMAGES, TRAIN_IMAGES,
    CORES, THREADS_PER_CORE,
    predict_b,
)
from generate_measured_smoke import (
    CLAIM_HEADROOM_PP, PAPER_DELTA_PCT,
    TABLE10_THREADS, TABLE11_EPOCHS, TABLE11_IMAGES, TABLE11_THREADS,
    bands_of, delta_pct, measured_execution_s, overall_mean,
    build as measured_build,
)

# SimConfig::default().seed and the residual grid salt
# (rust/src/calibration/residual.rs RESIDUAL_SALT).
SIM_SEED = 0x5EED
RESIDUAL_SALT = 0xC0DE_F17  # "code fit"

# Ridge regularizer (residual.rs LAMBDA).
LAMBDA = 1e-3

# SimConfig::default() constants folded in as (per-fit constant)
# features — the sensitivity report's top-ranked simulator knobs.
FWD_CYCLES_PER_OP = 31.0
EXEC_FRACTION = 0.75
OVERSUB_OVERHEAD = 0.05

# ArchSpec::total_weights() per paper architecture.
TOTAL_WEIGHTS = {"small": 8_545, "medium": 76_040, "large": 363_960}

MASK64 = (1 << 64) - 1

RESIDUAL_GRID_IDS = ["table9_residual", "table10_residual", "table11_residual"]
RESIDUAL_CLAIM_GRID = "table9_residual"


# ---------------------------------------------------------------------------
# Deterministic primitives (bit-exact ports of the Rust ones)
# ---------------------------------------------------------------------------

def fnv1a(data):
    """util-wide FNV-1a over bytes (rust/src/lab/store.rs)."""
    h = 0xCBF2_9CE4_8422_2325
    for b in data:
        h ^= b
        h = (h * 0x0000_0100_0000_01B3) & MASK64
    return h


class XorShift64:
    """nn::init::XorShift64, bit for bit (splitmix64 seed finalizer,
    xorshift64* stream)."""

    def __init__(self, seed):
        z = (seed + 0x9E37_79B9_7F4A_7C15) & MASK64
        z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & MASK64
        z ^= z >> 31
        self.state = z | 1

    def next_u64(self):
        x = self.state
        x ^= x >> 12
        x = (x ^ (x << 25)) & MASK64
        x ^= x >> 27
        self.state = x
        return (x * 0x2545_F491_4F6C_DD1D) & MASK64

    def next_below(self, n):
        return self.next_u64() % n


# ---------------------------------------------------------------------------
# The seeded training grid (residual.rs::training_runs)
# ---------------------------------------------------------------------------

def training_runs(arch, seed=SIM_SEED):
    """Workload variants x the Table IV thread ladder, in fit order
    (workload-outer, threads-inner so k-fold index splits mix both
    axes). Variants: the paper workload, its 2x and 4x Table XI
    scalings, and one jittered draw from the seeded stream."""
    ep = EPOCHS[arch]
    rng = XorShift64((seed ^ fnv1a(arch.encode())) ^ RESIDUAL_SALT)
    jitter = (
        15_000 + rng.next_below(45_001),
        2_500 + rng.next_below(7_501),
        5 + rng.next_below(ep),
    )
    workloads = [
        (TRAIN_IMAGES, TEST_IMAGES, ep),
        (2 * TRAIN_IMAGES, 2 * TEST_IMAGES, 2 * ep),
        (4 * TRAIN_IMAGES, 4 * TEST_IMAGES, 4 * ep),
        jitter,
    ]
    return [(i, it, e, p) for (i, it, e) in workloads for p in LADDER_THREADS]


# ---------------------------------------------------------------------------
# Features (strategy_c.rs FEATURES / feature vector)
# ---------------------------------------------------------------------------

FEATURE_NAMES = [
    "intercept",
    "ln_threads",
    "ln_threads_sq",
    "occupancy",
    "cpi",
    "oversub_flag",
    "ln_oversub",
    "ln_train_images",
    "ln_test_images_p1",
    "ln_epochs",
    "ln_total_weights",
    "fwd_cycles_per_op",
    "exec_fraction",
    "oversub_overhead",
]

CPI_LADDER = [1.0, 1.0, 1.5, 2.0]


def features(arch, i, it, ep, p):
    lp = math.log(float(p))
    occ = min(-(-p // CORES), THREADS_PER_CORE)
    cpi = CPI_LADDER[min(occ, len(CPI_LADDER)) - 1]
    hw = float(CORES * THREADS_PER_CORE)
    ln_oversub = max(math.log(float(p) / hw), 0.0) if p > CORES * THREADS_PER_CORE else 0.0
    return [
        1.0,
        lp,
        lp * lp,
        float(occ),
        cpi,
        1.0 if p > CORES * THREADS_PER_CORE else 0.0,
        ln_oversub,
        math.log(float(i)),
        math.log(float(it) + 1.0),
        math.log(float(ep)),
        math.log(float(TOTAL_WEIGHTS[arch])),
        FWD_CYCLES_PER_OP,
        EXEC_FRACTION,
        OVERSUB_OVERHEAD,
    ]


# ---------------------------------------------------------------------------
# Ridge fit (residual.rs::fit): normal equations + Gaussian elimination
# with partial pivoting, accumulation strictly in point order
# ---------------------------------------------------------------------------

def fit(points, lam=LAMBDA):
    """points: [(x: [f64], z: f64)] -> weights [f64]."""
    d = len(points[0][0])
    xtx = [[0.0] * d for _ in range(d)]
    xtz = [0.0] * d
    for (x, z) in points:
        for r in range(d):
            xr = x[r]
            row = xtx[r]
            for c in range(d):
                row[c] += xr * x[c]
            xtz[r] += xr * z
    for r in range(d):
        xtx[r][r] += lam
    # Gaussian elimination with partial pivoting.
    a = [xtx[r] + [xtz[r]] for r in range(d)]
    for col in range(d):
        piv = col
        for r in range(col + 1, d):
            if abs(a[r][col]) > abs(a[piv][col]):
                piv = r
        a[col], a[piv] = a[piv], a[col]
        pivval = a[col][col]
        for r in range(col + 1, d):
            f = a[r][col] / pivval
            if f == 0.0:
                continue
            for c in range(col, d + 1):
                a[r][c] -= f * a[col][c]
    w = [0.0] * d
    for r in range(d - 1, -1, -1):
        acc = a[r][d]
        for c in range(r + 1, d):
            acc -= a[r][c] * w[c]
        w[r] = acc / a[r][r]
    return w


def training_points(arch, seed=SIM_SEED):
    pts = []
    for (i, it, ep, p) in training_runs(arch, seed):
        measured = measured_execution_s(arch, i, it, ep, p)
        predicted = predict_b(arch, i, it, ep, p)
        pts.append((features(arch, i, it, ep, p),
                    math.log(measured / predicted)))
    return pts


def fit_arch(arch, seed=SIM_SEED):
    return fit(training_points(arch, seed))


def predict_c(weights, arch, i, it, ep, p):
    """StrategyC::predict: the (b) total scaled by exp(w . x)."""
    x = features(arch, i, it, ep, p)
    ratio = math.exp(sum(wi * xi for (wi, xi) in zip(weights, x)))
    return predict_b(arch, i, it, ep, p) * ratio


# ---------------------------------------------------------------------------
# The residual conformance grids (conformance::residual_grids):
# Tables IX-XI domains, strategies (b, c)
# ---------------------------------------------------------------------------

def grid_defs():
    def spec(archs, images, epochs, threads):
        doc = {
            "archs": archs,
            "threads": threads,
            "images": [list(pair) for pair in images],
        }
        if epochs:
            doc["epochs"] = epochs
        doc["strategies"] = ["b", "c"]
        doc["params"] = "paper"
        doc["measure"] = True
        return doc

    def enumerate_grid(archs, images, epochs, threads):
        out = []
        for arch in archs:
            eps = epochs if epochs else [EPOCHS[arch]]
            for (i, it) in images:
                for ep in eps:
                    for p in threads:
                        for s in ("b", "c"):
                            out.append((arch, i, it, ep, p, s))
        return out

    grids = []
    grids.append((
        "table9_residual",
        spec(ARCHS, [(TRAIN_IMAGES, TEST_IMAGES)], [], MEASURED_THREADS),
        enumerate_grid(ARCHS, [(TRAIN_IMAGES, TEST_IMAGES)], [],
                       MEASURED_THREADS),
    ))
    grids.append((
        "table10_residual",
        spec(ARCHS, [(TRAIN_IMAGES, TEST_IMAGES)], [], TABLE10_THREADS),
        enumerate_grid(ARCHS, [(TRAIN_IMAGES, TEST_IMAGES)], [],
                       TABLE10_THREADS),
    ))
    grids.append((
        "table11_residual",
        spec(["small"], TABLE11_IMAGES, TABLE11_EPOCHS, TABLE11_THREADS),
        enumerate_grid(["small"], TABLE11_IMAGES, TABLE11_EPOCHS,
                       TABLE11_THREADS),
    ))
    return grids


def evaluate(scenarios, weights_by_arch):
    rows = []
    for (arch, i, it, ep, p, s) in scenarios:
        if s == "b":
            predicted = predict_b(arch, i, it, ep, p)
        else:
            predicted = predict_c(weights_by_arch[arch], arch, i, it, ep, p)
        measured = measured_execution_s(arch, i, it, ep, p)
        rows.append((arch, i, it, ep, p, s, measured, predicted,
                     delta_pct(measured, predicted)))
    return rows


# ---------------------------------------------------------------------------
# Self-checks: ordering with margin, k-fold generalization, determinism
# ---------------------------------------------------------------------------

K_FOLDS = 4
KFOLD_TOL_PP = 3.0  # tests/calibration.rs kfold gate tolerance


def kfold_deltas(arch, k=K_FOLDS, seed=SIM_SEED):
    """(in-sample mean Δ%, held-out mean Δ%) of (c) over the training
    grid under an index-mod-k split — the Rust kfold gate, mirrored."""
    runs = training_runs(arch, seed)
    pts = training_points(arch, seed)
    full_w = fit(pts)
    in_sample, held_out = [], []
    for fold in range(k):
        train = [pt for (j, pt) in enumerate(pts) if j % k != fold]
        w = fit(train)
        for (j, (i, it, ep, p)) in enumerate(runs):
            measured = measured_execution_s(arch, i, it, ep, p)
            if j % k == fold:
                held_out.append(
                    delta_pct(measured, predict_c(w, arch, i, it, ep, p)))
    for (i, it, ep, p) in runs:
        measured = measured_execution_s(arch, i, it, ep, p)
        in_sample.append(
            delta_pct(measured, predict_c(full_w, arch, i, it, ep, p)))
    return (sum(in_sample) / len(in_sample), sum(held_out) / len(held_out))


def self_check(results, weights_by_arch):
    # The measured replication's own anchor suite first (it underlies
    # every residual target).
    _measured_results()
    # Determinism: refitting from the same seed is bit-identical;
    # another seed produces a different training grid.
    for arch in ARCHS:
        again = fit_arch(arch)
        assert weights_by_arch[arch] == again, arch
        assert training_runs(arch) == training_runs(arch), arch
        assert training_runs(arch, SIM_SEED ^ 0xBEEF) != training_runs(arch)
    # Ordering with margin: on every grid, each (arch, c) band mean sits
    # strictly below the (arch, b) band mean — with >= 20 % relative
    # headroom so libm replication noise can never flip the runtime
    # strict check.
    for gid, rows in results.items():
        means = {(b["arch"], b["strategy"]): b["mean_delta_pct"]
                 for b in bands_of(rows)}
        for arch in {r[0] for r in rows}:
            b_mean, c_mean = means[(arch, "b")], means[(arch, "c")]
            assert c_mean < 0.8 * b_mean, (gid, arch, c_mean, b_mean)
    # The claim: (c)'s overall Table IX mean beats (b)'s.
    b_overall = overall_mean(results[RESIDUAL_CLAIM_GRID], "b")
    c_overall = overall_mean(results[RESIDUAL_CLAIM_GRID], "c")
    assert c_overall < 0.8 * b_overall, (c_overall, b_overall)
    # k-fold held-out gate (tests/calibration.rs): held-out mean within
    # tolerance of in-sample, and below (b)'s Table IX band mean.
    t9_b = {b["arch"]: b["mean_delta_pct"]
            for b in bands_of(results[RESIDUAL_CLAIM_GRID])
            if b["strategy"] == "b"}
    for arch in ARCHS:
        ins, out = kfold_deltas(arch)
        assert out <= ins + KFOLD_TOL_PP, (arch, ins, out)
        assert out < t9_b[arch], (arch, out, t9_b[arch])


_MEASURED_CACHE = None


def _measured_results():
    """The measured-smoke replication's own self-check inputs (runs the
    anchor suite of generate_measured_smoke once)."""
    global _MEASURED_CACHE
    if _MEASURED_CACHE is None:
        _, res = measured_build()
        _MEASURED_CACHE = res
    return _MEASURED_CACHE


# ---------------------------------------------------------------------------
# Build
# ---------------------------------------------------------------------------

def build():
    weights = {arch: fit_arch(arch) for arch in ARCHS}
    results = {}
    grids_out = []
    for (gid, spec, scenarios) in grid_defs():
        rows = evaluate(scenarios, weights)
        results[gid] = rows
        grids_out.append({"id": gid, "spec": spec, "bands": bands_of(rows)})
    self_check(results, weights)
    claims = []
    # The (b) paper mean is the bar for both strategies: (b) must hold
    # its own claim on this domain, (c) must do at least as well.
    paper_b = sum(v[1] for v in PAPER_DELTA_PCT.values()) / 3.0
    for strategy in ("b", "c"):
        observed = overall_mean(results[RESIDUAL_CLAIM_GRID], strategy)
        claims.append({
            "strategy": strategy,
            "grid": RESIDUAL_CLAIM_GRID,
            "paper_mean_pct": paper_b,
            "ceiling_pct": max(paper_b, observed + CLAIM_HEADROOM_PP),
        })
    return {
        "kind": "micdl-conformance-baseline",
        "version": 1,
        "claims": claims,
        "grids": grids_out,
    }, results, weights


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--write", action="store_true",
                    help="overwrite baselines/residual_smoke.json "
                         "(default: self-check + print the bands only)")
    args = ap.parse_args()
    doc, results, weights = build()
    for arch in ARCHS:
        ins, out = kfold_deltas(arch)
        print(f"{arch}: weights {['%.4f' % w for w in weights[arch]]}")
        print(f"  kfold in-sample {ins:.3f}%  held-out {out:.3f}%")
    for grid in doc["grids"]:
        print(f"{grid['id']}: {len(results[grid['id']])} cells")
        for band in grid["bands"]:
            print(f"  {band['arch']}/{band['strategy']}: "
                  f"mean Δ {band['mean_delta_pct']:.3f}%  "
                  f"max Δ {band['max_delta_pct']:.3f}% "
                  f"@ p={band['max_at_threads']} "
                  f"({band['points']} points)")
    for claim in doc["claims"]:
        print(f"claim {claim['strategy']}: paper {claim['paper_mean_pct']:.2f}% "
              f"ceiling {claim['ceiling_pct']:.2f}%")
    if not args.write:
        print("self-check OK; pass --write to overwrite residual_smoke.json")
        return
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "residual_smoke.json")
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
